"""Sparse (edge-list) batched max-plus engine.

The dense engine (:mod:`repro.core.maxplus_vec`) scores a batch of
overlays as one ``[B, N, N]`` array, spending O(B·N²) memory and
O(B·N³) work per Karp evaluation regardless of how many arcs the
overlays actually have.  Designed overlays are *sparse* — rings carry N
arcs, degree-δ trees at most δ·N — so past N≈1k the dense path wastes
three orders of magnitude of both.  This module represents a batch of
delay digraphs as padded edge lists

    src[B, E] : int32  arc source vertex
    dst[B, E] : int32  arc destination vertex
    w[B, E]   : float  arc weight; ``-inf`` marks an absent (padding) arc

(an :class:`EdgeBatch`) and evaluates the same algorithms in O(B·N·E)
work with O(B·E) graph storage.  (Karp's formula still needs its
``[N+1, chunk, N]`` DP level table; like the dense engine, the numpy
path chunks the batch to bound that transient.)  The kernels:

* :func:`batched_cycle_time_sparse`      — multi-source Karp via one
  segment-max over edges per DP level (numpy, f32/f64);
* :func:`batched_cycle_time_sparse_jax`  — the same DP as a jittable JAX
  function (``lax.scan`` over levels, ``jax.ops.segment_max`` per
  level) — the kernel inside :func:`repro.core.topologies.search_overlays_jit`;
* :func:`batched_timing_recursion_sparse` — Eq. 4 timing recursion over
  edge lists (missing self-loops act as weight 0, matching the dense
  convention);
* :func:`batched_is_strongly_connected_sparse` /
  :func:`reachable_from_sparse` — frontier propagation along edges;
* :func:`scc_labels_sparse`              — forward–backward (coloring)
  SCC peeling, the standard edge-list formulation used by large-graph
  frameworks where the O(N²)-bit dense closure does not fit.

Padding convention
------------------

A padded arc must keep ``src``/``dst`` in ``[0, N)`` (0 is fine) and
``w = -inf``.  ``-inf`` is an absorbing element of max-plus — a padded
arc can never attain a segment max, and ``-inf + -inf = -inf`` raises no
NaNs because walk values are never ``+inf`` — so padding is exactly
equivalent to the arc not existing.  This is what makes a fixed
``[B, E_max]`` shape jit-friendly: rewire moves toggle arcs by writing
weights, never by reshaping.

Equivalence
-----------

Every function here is tested (``tests/test_maxplus_sparse.py``) to
agree with its dense counterpart — and therefore, transitively, with the
``*_legacy`` dict oracles of :mod:`repro.core.maxplus` — on random
digraphs in f32 and f64, including padded-edge and duplicate-arc cases
(duplicate arcs resolve to their max weight, same as a dense overwrite
with the larger value).
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from ..analysis.contracts import contract
from ..obs.spans import span_fn
from .maxplus_vec import NEG_INF, karp_from_levels, missing_mask

Arc = Tuple[int, int]

# Default cap on one chunk's Karp level-table storage (matches the dense
# engine's default).
_DEFAULT_DP_BYTES = 256 << 20


class EdgeBatch(NamedTuple):
    """A batch of B delay digraphs on a common vertex set ``[0, N)``.

    Attributes
    ----------
    src, dst:
        ``[B, E]`` int32 arc endpoints (``src`` -> ``dst``).
    w:
        ``[B, E]`` float arc weights; ``-inf`` marks padding (the arc
        does not exist in that graph).
    num_nodes:
        N, the common vertex count.
    """

    src: np.ndarray
    dst: np.ndarray
    w: np.ndarray
    num_nodes: int

    @property
    def batch(self) -> int:
        return self.src.shape[0]

    @property
    def max_edges(self) -> int:
        return self.src.shape[1]


@contract("[B,N,N]|[N,N]", ret="eb[B,E,N]")
def dense_to_edge_batch(W: np.ndarray, e_max: Optional[int] = None) -> EdgeBatch:
    """Convert a dense ``[B, N, N]`` (or ``[N, N]``) weight stack to a
    padded :class:`EdgeBatch`.

    ``e_max`` overrides the edge capacity (default: the max finite-arc
    count across the batch); extra slots are padding (``w = -inf``).
    """
    W = np.asarray(W)
    if W.ndim == 2:
        W = W[None]
    B, N, _ = W.shape
    finite = W > NEG_INF
    counts = finite.reshape(B, -1).sum(axis=1)
    E = int(counts.max()) if e_max is None else int(e_max)
    if E < counts.max():
        raise ValueError(f"e_max={E} < densest graph ({int(counts.max())} arcs)")
    src = np.zeros((B, max(E, 1)), dtype=np.int32)
    dst = np.zeros((B, max(E, 1)), dtype=np.int32)
    w = np.full((B, max(E, 1)), NEG_INF, dtype=W.dtype)
    for b in range(B):
        i, j = np.nonzero(finite[b])
        src[b, : i.size] = i
        dst[b, : j.size] = j
        w[b, : i.size] = W[b, i, j]
    return EdgeBatch(src, dst, w, N)


@contract("eb[B,E,N]", ret="[B,N,N]")
def edge_batch_to_dense(eb: EdgeBatch) -> np.ndarray:
    """Inverse of :func:`dense_to_edge_batch`: ``[B, N, N]`` with ``-inf``
    holes.  Duplicate arcs keep their max weight (max-plus semantics)."""
    B, E = eb.src.shape
    N = eb.num_nodes
    flat = np.full(B * N * N, NEG_INF, dtype=eb.w.dtype)
    keys = (
        np.repeat(np.arange(B, dtype=np.int64), E) * (N * N)
        + eb.src.ravel().astype(np.int64) * N
        + eb.dst.ravel().astype(np.int64)
    )
    np.maximum.at(flat, keys, eb.w.ravel())
    return flat.reshape(B, N, N)


# ---------------------------------------------------------------------------
# Segment-max plumbing (numpy)


class _Segments(NamedTuple):
    """Precomputed sort-order for repeated segment maxes over fixed keys."""

    order: np.ndarray  # [B*E] permutation sorting keys
    starts: np.ndarray  # group start offsets into the sorted stream
    group_keys: np.ndarray  # the key of each group


def _segments_by(keys: np.ndarray) -> _Segments:
    order = np.argsort(keys, kind="stable")
    ks = keys[order]
    starts = np.flatnonzero(np.r_[True, ks[1:] != ks[:-1]])
    return _Segments(order, starts, ks[starts])


def _segment_max(
    vals: np.ndarray, seg: _Segments, out_size: int, dtype
) -> np.ndarray:
    """Max of ``vals`` per key group, scattered into ``[out_size]``
    (``-inf`` where a key never occurs).  ``vals`` is flat ``[B*E]``."""
    out = np.full(out_size, NEG_INF, dtype=dtype)
    if seg.starts.size:
        out[seg.group_keys] = np.maximum.reduceat(vals[seg.order], seg.starts)
    return out


def _dst_segments(eb: EdgeBatch) -> _Segments:
    B, E = eb.src.shape
    keys = (
        np.repeat(np.arange(B, dtype=np.int64), E) * eb.num_nodes
        + eb.dst.ravel().astype(np.int64)
    )
    return _segments_by(keys)


# ---------------------------------------------------------------------------
# Batched Karp (numpy)


@span_fn("engine.karp_sparse")
@contract("eb[B,E,N]", ret="[B]")
def batched_cycle_time_sparse(
    eb: EdgeBatch,
    *,
    dtype: Optional[np.dtype] = None,
    max_dp_bytes: int = _DEFAULT_DP_BYTES,
) -> np.ndarray:
    """Maximum cycle mean of every graph in an edge-list batch.

    Same multi-source Karp DP as
    :func:`repro.core.maxplus_vec.batched_cycle_time`, but each level is
    one segment-max over the E arcs instead of an N×N broadcast sweep:
    O(B·N·E) work, which beats the dense O(B·N³) whenever E ≪ N².

    Parameters
    ----------
    eb:
        :class:`EdgeBatch`; padding arcs (``w = -inf``) are ignored.
    dtype:
        DP dtype; defaults to ``eb.w.dtype``.  f64 reproduces the dense
        engine bit-for-bit, f32 halves memory traffic for search-grade
        candidate ranking.
    max_dp_bytes:
        Cap on one chunk's ``[N+1, chunk, N]`` Karp level table (the
        formula needs all levels); the batch is chunked to stay under it,
        mirroring the dense engine.

    Returns
    -------
    ``[B]`` max cycle means (``-inf`` for acyclic graphs).
    """
    dtype = np.dtype(dtype or eb.w.dtype)
    B, E = eb.src.shape
    N = eb.num_nodes
    if N == 0 or B == 0:
        return np.full(B, NEG_INF, dtype=dtype)
    per_graph_dp = (N + 1) * N * dtype.itemsize
    chunk = max(1, min(B, max_dp_bytes // max(per_graph_dp, 1)))
    out = np.empty(B, dtype=dtype)
    for lo in range(0, B, chunk):
        sub = EdgeBatch(
            eb.src[lo : lo + chunk],
            eb.dst[lo : lo + chunk],
            eb.w[lo : lo + chunk],
            N,
        )
        out[lo : lo + chunk] = _sparse_karp_chunk(sub, dtype)
    return out


@contract("N", "E", "B")
def cycle_time_engine(num_nodes: int, num_edges: int, batch: int) -> str:
    """Pick the winning Karp engine for a scoring problem size.

    The dense ``[B, N, N]`` sweep beats the edge-list segment max at
    small N (BENCH_sparse_search.json: 124 ms vs 196 ms at N=64 — short
    contiguous rows amortize better than argsort+reduceat segments)
    and loses badly once E ≪ N² (678 ms vs 414 ms at N=256, 12.6 s vs
    2.0 s at N=1024).  The measured crossover sits between N=64 and
    N=256; the heuristic also keeps dense whenever the edge list is
    nearly square (E ≥ N²/4), where segment bookkeeping is pure
    overhead.  Returns ``"dense"`` or ``"sparse"``.
    """
    n, e = int(num_nodes), int(num_edges)
    if n <= 128 or e * 4 >= n * n:
        return "dense"
    return "sparse"


@contract("eb[B,E,N]", ret="[B]")
def batched_cycle_time_auto(
    eb: EdgeBatch, *, dtype: Optional[np.dtype] = None
) -> np.ndarray:
    """Size-dispatched exact cycle time: dense engine below the
    crossover of :func:`cycle_time_engine`, edge-list engine above.

    Both engines run the same f64 Karp DP, so the dispatch never
    changes results, only wall clock (the equivalence suite asserts
    bit identity between them).  This is the scoring entry point the
    searches re-price final candidates through.
    """
    B, E = eb.src.shape
    N = eb.num_nodes
    if cycle_time_engine(N, E, B) == "sparse":
        return batched_cycle_time_sparse(eb, dtype=dtype)
    from .maxplus_vec import batched_cycle_time

    dt = np.dtype(dtype or eb.w.dtype)
    W = np.full((B, N, N), NEG_INF, dtype=dt)
    present = ~missing_mask(eb.w)
    bb = np.broadcast_to(np.arange(B)[:, None], eb.src.shape)
    # Parallel arcs collapse under max — same semantics as the sparse
    # segment reduction.
    np.maximum.at(
        W, (bb[present], eb.src[present], eb.dst[present]),
        eb.w.astype(dt, copy=False)[present],
    )
    return np.atleast_1d(batched_cycle_time(W, dtype=dt))


def _sparse_karp_chunk(eb: EdgeBatch, dtype: np.dtype) -> np.ndarray:
    B, E = eb.src.shape
    N = eb.num_nodes
    w = eb.w.astype(dtype, copy=False)
    seg = _dst_segments(eb)
    bb = np.arange(B)[:, None]
    D = np.empty((N + 1, B, N), dtype=dtype)
    D[0] = 0.0
    cur = D[0]
    for k in range(1, N + 1):
        vals = cur[bb, eb.src] + w  # [B, E] walk extensions
        cur = _segment_max(vals.ravel(), seg, B * N, dtype).reshape(B, N)
        D[k] = cur
    return karp_from_levels(D)


@contract("#E", "#E", "#E", "N")
def cycle_time_sparse(
    src: Sequence[int], dst: Sequence[int], w: Sequence[float], num_nodes: int
) -> float:
    """Max cycle mean of a single edge-list digraph (flat ``[E]`` arrays)."""
    eb = EdgeBatch(
        np.asarray(src, dtype=np.int32)[None],
        np.asarray(dst, dtype=np.int32)[None],
        np.asarray(w, dtype=np.float64)[None],
        num_nodes,
    )
    return float(batched_cycle_time_sparse(eb)[0])


# ---------------------------------------------------------------------------
# Batched Karp (JAX)


def _padded_edge_layout(src, dst, w, num_nodes: int, max_in_degree: int):
    """``[B, N*D]`` gather layout for the degree-padded segment max.

    For each destination ``v`` its (up to ``D``) present in-arcs occupy
    slots ``v*D .. v*D+D-1`` as (source index, weight); unused slots
    point at node 0 with ``-inf`` weight so they fold away under max.
    Absent arcs (``-inf`` weight) never consume a slot.  Present arcs
    beyond ``D`` per destination are silently dropped — callers must
    guarantee the in-degree bound (the rewire climb passes its degree
    cap plus transient headroom).
    """
    import jax
    import jax.numpy as jnp

    B, E = src.shape
    N, D = int(num_nodes), int(max_in_degree)
    src = jnp.asarray(src, dtype=jnp.int32)
    dst = jnp.asarray(dst, dtype=jnp.int32)
    w = jnp.asarray(w)
    # Absent arcs sort into a virtual segment N so real arcs of a
    # destination are ranked only against each other.
    key = jnp.where(jnp.isneginf(w), N, dst).astype(jnp.int32)
    order = jnp.argsort(key, axis=1, stable=True)
    sd = jnp.take_along_axis(key, order, axis=1)
    ss = jnp.take_along_axis(src, order, axis=1)
    ws = jnp.take_along_axis(w, order, axis=1)
    first = jax.vmap(lambda row: jnp.searchsorted(row, row, side="left"))(sd)
    rank = jnp.arange(E, dtype=jnp.int32)[None, :] - first.astype(jnp.int32)
    slot = jnp.where((rank < D) & (sd < N), sd * D + rank, N * D)
    table = jnp.full((B, N * D + 1), E, dtype=jnp.int32)
    table = table.at[
        jnp.arange(B, dtype=jnp.int32)[:, None], slot
    ].set(jnp.broadcast_to(jnp.arange(E, dtype=jnp.int32)[None, :], (B, E)))
    table = table[:, : N * D]
    ssp = jnp.concatenate([ss, jnp.zeros((B, 1), dtype=ss.dtype)], axis=1)
    wsp = jnp.concatenate(
        [ws, jnp.full((B, 1), NEG_INF, dtype=w.dtype)], axis=1)
    gsrc = jnp.take_along_axis(ssp, table, axis=1)
    gw = jnp.take_along_axis(wsp, table, axis=1)
    return gsrc, gw


@contract("[B,E]", "[B,E]", "[B,E]", "N", ret="[B]", max_in_degree="*D")
def batched_cycle_time_sparse_jax(src, dst, w, num_nodes: int, *,
                                  kernel: str = "auto",
                                  max_in_degree=None):
    """Jittable JAX version of :func:`batched_cycle_time_sparse`.

    Parameters
    ----------
    src, dst:
        ``[B, E]`` int32 arc endpoints (may be traced).
    w:
        ``[B, E]`` arc weights, ``-inf`` padding.
    num_nodes:
        N — must be static under ``jax.jit`` (it fixes the scan length
        and the segment count).
    kernel:
        Segment-max implementation: ``"auto"`` (Pallas on TPU, the
        degree-padded gather when ``max_in_degree`` is given, else
        ``jax.ops.segment_max``), or an explicit ``"xla"`` /
        ``"padded"`` / ``"pallas"``.  All choices are bit-identical for
        NaN-free inputs (``"padded"`` additionally requires the
        in-degree bound to hold).
    max_in_degree:
        Static bound on per-destination present-arc count, enabling the
        ``"padded"`` formulation that sidesteps XLA's serial
        scatter-max on CPU.

    Returns
    -------
    ``[B]`` max cycle means.  Wrap in ``jax.jit`` at the call site (with
    ``static_argnums`` for ``num_nodes``) to cache compilation per
    (B, E, N).
    """
    import jax
    import jax.numpy as jnp

    from ..kernels.segment_max import (
        edge_segment_max_pallas,
        select_segment_max_impl,
    )

    w = jnp.asarray(w)
    B, E = src.shape
    N = int(num_nodes)
    impl = select_segment_max_impl(
        kernel, padded=max_in_degree is not None)
    D0 = jnp.zeros((B, N), dtype=w.dtype)

    if impl == "padded":
        if max_in_degree is None:
            raise ValueError("kernel='padded' needs max_in_degree")
        D = int(max_in_degree)
        gsrc, gw = _padded_edge_layout(src, dst, w, N, D)

        def step(cur, _):
            vals = jnp.take_along_axis(cur, gsrc, axis=1) + gw
            nxt = jnp.max(vals.reshape(B, N, D), axis=2)
            return nxt, nxt

    elif impl == "pallas":
        seg = jnp.asarray(dst, dtype=jnp.int32)

        def step(cur, _):
            vals = jnp.take_along_axis(cur, src, axis=1) + w
            nxt = edge_segment_max_pallas(vals, seg, N)
            return nxt, nxt

    else:  # "xla"
        seg_ids = (jnp.arange(B, dtype=jnp.int32)[:, None] * N + dst).ravel()

        def step(cur, _):
            vals = jnp.take_along_axis(cur, src, axis=1) + w
            nxt = jax.ops.segment_max(
                vals.ravel(), seg_ids, num_segments=B * N
            ).reshape(B, N)
            return nxt, nxt

    _, levels = jax.lax.scan(step, D0, None, length=N)  # D_1..D_N
    Dn = levels[-1]
    allk = jnp.concatenate([D0[None], levels[:-1]], axis=0)  # D_0..D_{N-1}
    denom = (N - jnp.arange(N)).astype(w.dtype)
    ratios = (Dn[None, :, :] - allk) / denom[:, None, None]
    ratios = jnp.where(jnp.isnan(ratios), jnp.inf, ratios)
    mins = jnp.min(ratios, axis=0)
    neg = jnp.array(NEG_INF, dtype=w.dtype)
    mins = jnp.where(jnp.isneginf(Dn), neg, mins)
    return jnp.max(mins, axis=1)


# ---------------------------------------------------------------------------
# Timing recursion (Eq. 4) over edge lists


@contract("eb[B,E,N]", "R", "*[B,N]", ret="[B,R+1,N]")
def batched_timing_recursion_sparse(
    eb: EdgeBatch, num_rounds: int, t0: Optional[np.ndarray] = None
) -> np.ndarray:
    """Eq. 4 max-plus recursion over an edge-list batch.

    ``t_j(k+1) = max over arcs (i -> j) of t_i(k) + w(i, j)``, with a
    missing self-loop acting as weight 0 (a silo with no modeled
    computation delay still observes its own previous start) — matching
    :func:`repro.core.maxplus_vec.batched_timing_recursion` exactly.

    Parameters
    ----------
    eb:
        :class:`EdgeBatch` of B delay digraphs.
    num_rounds:
        R, the number of rounds to evolve.
    t0:
        Optional ``[B, N]`` initial start times (default zeros).

    Returns
    -------
    ``[B, R+1, N]`` start-time trajectories.
    """
    B, E = eb.src.shape
    N = eb.num_nodes
    dtype = np.float64
    w = eb.w.astype(dtype, copy=False)
    present = w > NEG_INF
    has_self = np.zeros((B, N), dtype=bool)
    self_arc = present & (eb.src == eb.dst)
    bb = np.arange(B)[:, None]
    np.logical_or.at(has_self, (bb * np.ones_like(eb.src), eb.src), self_arc)
    seg = _dst_segments(eb)
    t = (
        np.zeros((B, N), dtype=dtype)
        if t0 is None
        else np.asarray(t0, dtype=dtype).copy()
    )
    out = np.empty((B, num_rounds + 1, N), dtype=dtype)
    out[:, 0] = t
    for k in range(num_rounds):
        vals = t[bb, eb.src] + w
        nxt = _segment_max(vals.ravel(), seg, B * N, dtype).reshape(B, N)
        t = np.maximum(nxt, np.where(has_self, NEG_INF, t))
        out[:, k + 1] = t
    return out


@contract("[E]", "[E]", "[U,E]", "[C,R]", "N", "*[C,N]", ret="[C,R+1,N]")
def timing_recursion_unique_rounds_sparse(
    src: np.ndarray,
    dst: np.ndarray,
    w_unique: np.ndarray,
    round_ids: np.ndarray,
    num_nodes: int,
    t0: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Eq. 4 recursion with round-varying weights drawn from a pool of
    distinct weight rows — the kernel behind randomized-schedule (MATCHA)
    pricing.

    A randomized plan distribution samples a fresh overlay every round,
    but the *candidate arc pool* (matching arcs + computation self-loops)
    is fixed: only the weights change (``-inf`` = the arc was not sampled
    this round), and at realistic budgets many rounds repeat the same
    activation subset.  So the batch is

    ``src``, ``dst``:
        ``[E]`` int arc endpoints, shared by every chain and round.
    ``w_unique``:
        ``[U, E]`` distinct weight rows (``-inf`` marks an absent arc).
    ``round_ids``:
        ``[C, R]`` int — round k of chain c uses graph
        ``(src, dst, w_unique[round_ids[c, k]])``.  C is the number of
        independent Monte-Carlo chains (e.g. budgets × seeds).

    The full ``[C, R, E]`` stack is never materialized: each step gathers
    its ``[C, E]`` weight rows from the pool.  A vertex with no present
    self-loop at round k observes its own previous start (weight 0),
    matching :func:`batched_timing_recursion_sparse`.

    Returns ``[C, R+1, N]`` start-time trajectories (``t0``: optional
    ``[C, N]`` initial starts, default zeros).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    w_unique = np.asarray(w_unique, dtype=np.float64)
    round_ids = np.asarray(round_ids, dtype=np.int64)
    if w_unique.ndim != 2 or w_unique.shape[1] != src.shape[0]:
        raise ValueError(
            f"expected w_unique [U, E] with E == len(src); got "
            f"{w_unique.shape} vs {src.shape[0]} arcs"
        )
    if round_ids.ndim != 2:
        raise ValueError(f"expected round_ids [C, R], got {round_ids.shape}")
    C, R = round_ids.shape
    E = src.shape[0]
    N = int(num_nodes)
    self_arc = src == dst
    # Cheap common case first: every vertex has an always-present self
    # loop (Eq. 3 pools), so the carry-over merge is a no-op everywhere.
    sv = src[self_arc]
    all_self = (
        np.unique(sv).size == N
        and bool((w_unique[:, self_arc] > NEG_INF).all())
    )
    has_self_u = None
    if not all_self:
        # has_self_u[u, v]: does weight row u carry a self-loop at v?
        has_self_u = np.zeros((w_unique.shape[0], N), dtype=bool)
        if sv.size:
            np.logical_or.at(
                has_self_u,
                (np.arange(w_unique.shape[0])[:, None], sv[None, :]),
                w_unique[:, self_arc] > NEG_INF,
            )
    t = (
        np.zeros((C, N), dtype=np.float64)
        if t0 is None
        else np.asarray(t0, dtype=np.float64).copy()
    )
    out = np.empty((C, R + 1, N), dtype=np.float64)
    out[:, 0] = t
    # Fast path: when every vertex owns at least one arc slot (true for
    # Eq. 3 pools, whose N computation self-loops are always present) a
    # dst-presorted reduceat yields [C, N] directly — no flatten, no
    # scatter — and the recursion is three numpy calls per round.
    order = np.argsort(dst, kind="stable")
    dsts = dst[order]
    group_starts = np.flatnonzero(np.r_[True, dsts[1:] != dsts[:-1]])
    full_cover = np.array_equal(dsts[group_starts], np.arange(N))
    if full_cover:
        srcs = src[order]
        # Callers that pre-sort arcs by dst (the pricing hot path) skip
        # this whole-pool column gather.
        wu = w_unique if np.array_equal(dsts, dst) else w_unique[:, order]
        ids_t = np.ascontiguousarray(round_ids.T)  # [R, C] row per step
        reduceat, maximum = np.maximum.reduceat, np.maximum
        for k in range(R):
            ids_k = ids_t[k]
            vals = t[:, srcs]
            vals += wu[ids_k]
            nxt = reduceat(vals, group_starts, axis=1)
            t = nxt if all_self else maximum(
                nxt, np.where(has_self_u[ids_k], NEG_INF, t)
            )
            out[:, k + 1] = t
        return out
    seg = _segments_by(
        (np.repeat(np.arange(C, dtype=np.int64), E) * N + np.tile(dst, C))
    )
    for k in range(R):
        vals = t[:, src] + w_unique[round_ids[:, k]]
        nxt = _segment_max(vals.ravel(), seg, C * N, np.float64).reshape(C, N)
        t = nxt if all_self else np.maximum(
            nxt, np.where(has_self_u[round_ids[:, k]], NEG_INF, t)
        )
        out[:, k + 1] = t
    return out


@contract("[E]", "[E]", "[C,R,E]", "N", "*[C,N]", ret="[C,R+1,N]")
def timing_recursion_time_varying_sparse(
    src: np.ndarray,
    dst: np.ndarray,
    w: np.ndarray,
    num_nodes: int,
    t0: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Eq. 4 recursion with a dense ``[C, R, E]`` round-varying weight
    stack over a fixed arc layout.

    Convenience wrapper over :func:`timing_recursion_unique_rounds_sparse`
    with every (chain, round) treated as its own weight row — use the
    unique-rounds form directly when rounds repeat activation subsets.
    Returns ``[C, R+1, N]``.
    """
    w = np.asarray(w, dtype=np.float64)
    if w.ndim != 3 or w.shape[-1] != np.asarray(src).shape[0]:
        raise ValueError(
            f"expected w [C, R, E] with E == len(src); got {w.shape} vs "
            f"{np.asarray(src).shape[0]} arcs"
        )
    C, R, E = w.shape
    ids = np.arange(C * R, dtype=np.int64).reshape(C, R)
    return timing_recursion_unique_rounds_sparse(
        src, dst, w.reshape(C * R, E), ids, num_nodes, t0
    )


@contract("[E]", "[E]", "[C,R,E]", "N", "*[C,N]", ret="[C,R+1,N]")
def timing_recursion_time_varying_sparse_jax(src, dst, w, num_nodes: int,
                                             t0=None, *,
                                             kernel: str = "auto"):
    """Jittable JAX twin of :func:`timing_recursion_time_varying_sparse`.

    Same contract (``src``/``dst`` ``[E]``, ``w`` ``[C, R, E]``, returns
    ``[C, R+1, N]``) as one ``lax.scan`` over rounds with a segment-max
    per step, so a whole budget-sweep fuses into a single device
    computation.  ``num_nodes`` must be static under ``jax.jit``.
    Assumes every vertex has a present self-loop each round (true for
    Eq. 3 pricing, whose computation self-loops are always active) — the
    per-round carry-over special case is host-path-only.  ``kernel``
    picks the segment-max implementation (``"auto"`` = Pallas on TPU,
    ``jax.ops.segment_max`` elsewhere; bit-identical either way).
    """
    import jax
    import jax.numpy as jnp

    from ..kernels.segment_max import (
        edge_segment_max_pallas,
        select_segment_max_impl,
    )

    w = jnp.asarray(w)
    C, R, E = w.shape
    N = int(num_nodes)
    src = jnp.asarray(src, dtype=jnp.int32)
    dst = jnp.asarray(dst, dtype=jnp.int32)
    impl = select_segment_max_impl(kernel)
    seg_ids = (jnp.arange(C, dtype=jnp.int32)[:, None] * N + dst[None, :]).ravel()
    t0 = (
        jnp.zeros((C, N), dtype=w.dtype)
        if t0 is None
        else jnp.asarray(t0, dtype=w.dtype)
    )

    if impl == "pallas":
        seg_rows = jnp.broadcast_to(dst[None, :], (C, E))

        def step(t, wk):
            vals = t[:, src] + wk
            nxt = edge_segment_max_pallas(vals, seg_rows, N)
            return nxt, nxt

    else:

        def step(t, wk):
            vals = t[:, src] + wk
            nxt = jax.ops.segment_max(
                vals.ravel(), seg_ids, num_segments=C * N
            ).reshape(C, N)
            return nxt, nxt

    _, levels = jax.lax.scan(step, t0, jnp.swapaxes(w, 0, 1))  # [R, C, N]
    return jnp.concatenate([t0[:, None, :], jnp.swapaxes(levels, 0, 1)], axis=1)


# ---------------------------------------------------------------------------
# Reachability / SCC over edge lists


@contract("eb[B,E,N]", ret="[B,N]")
def reachable_from_sparse(eb: EdgeBatch, start: int = 0) -> np.ndarray:
    """``[B, N]`` bool: vertices reachable from ``start`` (inclusive) by
    the present arcs of each graph.  Frontier propagation to a fixed
    point — at most N-1 sweeps of O(E) each."""
    B, E = eb.src.shape
    N = eb.num_nodes
    present = (eb.w > NEG_INF) & (eb.src != eb.dst)
    seg = _dst_segments(eb)
    bb = np.arange(B)[:, None]
    reach = np.zeros((B, N), dtype=bool)
    reach[:, start] = True
    for _ in range(max(N - 1, 0)):
        vals = (reach[bb, eb.src] & present).ravel().astype(np.int8)
        hop = _segment_max(vals, seg, B * N, np.float64).reshape(B, N) > 0
        new = reach | hop
        if np.array_equal(new, reach):
            break
        reach = new
    return reach


def _reversed_batch(eb: EdgeBatch) -> EdgeBatch:
    return EdgeBatch(eb.dst, eb.src, eb.w, eb.num_nodes)


@contract("eb[B,E,N]", ret="[B]")
def batched_is_strongly_connected_sparse(eb: EdgeBatch) -> np.ndarray:
    """``[B]`` bool: is each edge-list graph strongly connected?

    Strong iff every vertex both reaches and is reached by vertex 0
    (self-loops ignored) — agrees with
    :func:`repro.core.maxplus_vec.batched_is_strongly_connected` on the
    densified graph.
    """
    fwd = reachable_from_sparse(eb)
    bwd = reachable_from_sparse(_reversed_batch(eb))
    return np.all(fwd & bwd, axis=1)


@contract("[E]", "[E]", "N", ret="[N]")
def scc_labels_sparse(
    src: np.ndarray, dst: np.ndarray, num_nodes: int
) -> np.ndarray:
    """Strongly-connected-component label per vertex of one edge-list
    digraph (flat ``[E]`` int arrays; self-loops ignored).

    Forward–backward peeling: pick the smallest unlabeled vertex, its
    SCC is (reachable ∩ co-reachable) within the unlabeled set, repeat.
    Each peel is O(N·E) worst case; the expected number of peels is small
    on the power-law-ish graphs this engine targets (the classic FW-BW /
    coloring argument).  For small N the dense matrix-power
    :func:`repro.core.maxplus_vec.scc_labels` is faster; for pathological
    chains its Tarjan fallback is.  Labels induce the same partition as
    both (tested), though label *values* may differ.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    N = int(num_nodes)
    labels = np.full(N, -1, dtype=np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    ncomp = 0
    while True:
        unlabeled = np.flatnonzero(labels < 0)
        if unlabeled.size == 0:
            return labels
        pivot = int(unlabeled[0])
        live = labels < 0
        alive = live[src] & live[dst]
        s, d = src[alive], dst[alive]
        fwd = _reach_one(s, d, N, pivot, live)
        bwd = _reach_one(d, s, N, pivot, live)
        comp = fwd & bwd & live
        labels[comp] = ncomp
        ncomp += 1


def _reduced_potentials(
    s: np.ndarray, d: np.ndarray, wr: np.ndarray, N: int, eps: float
) -> np.ndarray:
    """Longest-path potentials under reduced weights ``wr = w - tau``.

    With every cycle's reduced mean <= 0 the sweep reaches its fixed
    point within N iterations; the result satisfies the feasibility
    certificate ``pot[s] + wr <= pot[d]`` (up to ``eps``) on every arc.
    """
    seg = _segments_by(d)
    pot = np.zeros(N, dtype=np.float64)
    for _ in range(N):
        cand = _segment_max(pot[s] + wr, seg, N, np.float64)
        nxt = np.maximum(pot, cand)
        if np.all(nxt <= pot + eps):
            return nxt
        pot = nxt
    return pot


@contract("[E]", "[E]", "[E]", "N")
def critical_circuit_sparse(
    src: np.ndarray,
    dst: np.ndarray,
    w: np.ndarray,
    num_nodes: int,
    *,
    tau: Optional[float] = None,
) -> Tuple[float, list]:
    """(tau, circuit) attaining the max cycle mean of one edge-list
    digraph — the sparse analogue of
    :func:`repro.core.maxplus_vec.critical_circuit_dense` (kept as the
    oracle), so bottleneck explanation never materializes an ``[N, N]``
    matrix: O(N·E) work, O(N + E) extra memory.

    ``src``/``dst``/``w`` are flat ``[E]`` arrays (``-inf`` = padding).
    Longest-path potentials under the reduced weights ``w - tau`` converge
    in <= N segment-max sweeps; the *tight* arcs
    ``pot[src] + w' >= pot[dst]`` form a subgraph whose non-trivial SCCs
    (plus tight self-loops) carry exactly the circuits of mean ``tau``;
    the returned circuit is a deterministic walk inside one of them,
    closed as ``[v0, ..., v0]`` (empty for acyclic graphs).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    w = np.asarray(w, dtype=np.float64)
    N = int(num_nodes)
    if tau is None:
        tau = float(
            batched_cycle_time_sparse(
                EdgeBatch(
                    src[None].astype(np.int32), dst[None].astype(np.int32),
                    w[None], N,
                )
            )[0]
        )
    if missing_mask(tau) or N == 0:
        return NEG_INF, []
    present = w > NEG_INF
    s, d = src[present], dst[present]
    wr = w[present] - tau
    eps = 1e-9 * max(1.0, abs(tau))
    pot = _reduced_potentials(s, d, wr, N, eps)
    tight = pot[s] + wr >= pot[d] - 10 * eps
    ts, td = s[tight], d[tight]
    if ts.size == 0:  # numerically degenerate; caller falls back to dense
        return tau, []
    self_loops = ts[ts == td]
    labels = scc_labels_sparse(ts, td, N)
    counts = np.bincount(labels, minlength=N if labels.size else 0)
    on_cycle = np.zeros(N, dtype=bool)
    on_cycle[self_loops] = True
    multi = counts[labels] >= 2 if labels.size else np.zeros(0, dtype=bool)
    on_cycle[np.flatnonzero(multi)] = True
    hits = np.flatnonzero(on_cycle)
    if hits.size == 0:
        return tau, []
    v0 = int(hits[0])
    if counts.size == 0 or counts[labels[v0]] < 2:
        return tau, [v0, v0]  # tight self-loop
    # Deterministic walk over tight arcs restricted to v0's tight SCC:
    # every vertex there has a tight successor inside the SCC, so the
    # walk revisits a vertex within N steps; any closed tight walk has
    # reduced mean exactly 0, i.e. original mean exactly tau.
    comp = labels[v0]
    in_comp = (labels[ts] == comp) & (labels[td] == comp) & (ts != td)
    cs, cd = ts[in_comp], td[in_comp]
    order = np.lexsort((cd, cs))
    cs, cd = cs[order], cd[order]
    starts = np.searchsorted(cs, np.arange(N))
    ends = np.searchsorted(cs, np.arange(N) + 1)
    pos = {v0: 0}
    walk = [v0]
    cur = v0
    while True:
        lo, hi = starts[cur], ends[cur]
        assert hi > lo, "tight SCC lost the certified circuit"
        cur = int(cd[lo])
        if cur in pos:
            return tau, walk[pos[cur] :] + [cur]
        pos[cur] = len(walk)
        walk.append(cur)


def _reach_one(
    src: np.ndarray, dst: np.ndarray, n: int, start: int, live: np.ndarray
) -> np.ndarray:
    reach = np.zeros(n, dtype=bool)
    reach[start] = True
    while True:
        hop = np.zeros(n, dtype=bool)
        np.logical_or.at(hop, dst, reach[src])
        new = reach | (hop & live)
        if np.array_equal(new, reach):
            return reach
        reach = new


# ---------------------------------------------------------------------------
# Delta-evaluated cycle-time pricing (incremental re-pricing for rewire
# searches: a move touches O(deg) arcs, so most proposals re-price in
# O(deg) instead of a full O(N·E) Karp pass)


class PricedMove(NamedTuple):
    """The result of :meth:`DeltaPricer.price` — pass to
    :meth:`DeltaPricer.commit` to apply the move.

    ``tau`` is the exact max cycle mean of the *proposed* graph; ``kind``
    records which pricing path produced it (``"fast"``: certificate
    untouched, O(changed arcs); ``"propagated"``: local potential
    repair from the touched endpoints; ``"reanchor"``: full Karp)."""

    tau: float
    kind: str
    slots: np.ndarray
    src: np.ndarray
    dst: np.ndarray
    w: np.ndarray
    pot: Optional[np.ndarray]
    crit_arcs: Optional[frozenset]


class DeltaPricer:
    """Incremental max-cycle-mean pricing of one edge-list digraph under
    a stream of arc rewires (the hill-climb hot loop).

    The pricer maintains, alongside the graph itself, a *certificate* of
    its cycle time tau: longest-path potentials ``pot`` under the reduced
    weights ``w - tau`` (feasibility ``pot[s] + w - tau <= pot[d]`` on
    every arc proves every cycle mean <= tau) and one cached critical
    circuit attaining tau (proving some cycle mean == tau).  A proposed
    move — any set of slot rewrites ``(slot, src', dst', w')`` — is then
    priced by checking how it interacts with the certificate:

    * arcs it *weakens* (weight drop / removal / endpoint change) can
      only lower cycle means; if none lies on the cached critical
      circuit, that circuit still attains tau — the lower bound stands;
    * arcs it *strengthens* can only raise cycle means; each is checked
      against the potentials, and violations trigger a bounded local
      propagation (Bellman sweeps from the touched endpoints only).  If
      the propagation converges, the upper bound is repaired at the same
      tau; if any vertex updates more than N times there is a positive
      reduced cycle, i.e. tau genuinely rose.

    Only when a bound actually breaks (critical arc weakened, or a
    positive cycle appears) does the pricer fall back to a full Karp
    re-anchor (:func:`batched_cycle_time_sparse` — the equivalence
    oracle) on the proposed graph.  Random rewire proposals touch the
    certificate with probability ~deg/E, so the common case prices in
    O(deg) work: the order-of-magnitude that makes hill climbs feasible
    at N ~ 10^4.

    Exactness: the returned tau always equals full-Karp-from-scratch on
    the current graph, up to the feasibility tolerance ``eps`` (scale ×
    1e-9); on the fast paths it *is* the previously anchored Karp value,
    bit-for-bit (``tests/test_delta_pricing.py`` property-checks bit
    equality in f64 over random move sequences, including moves that
    disconnect and reconnect the graph).

    Not thread-safe; one pricer per climb state.
    """

    def __init__(
        self,
        src: np.ndarray,
        dst: np.ndarray,
        w: np.ndarray,
        num_nodes: int,
        *,
        dtype=np.float64,
    ):
        self.num_nodes = int(num_nodes)
        self._dtype = np.dtype(dtype)
        self._src = np.array(src, dtype=np.int64)
        self._dst = np.array(dst, dtype=np.int64)
        self._w = np.array(w, dtype=self._dtype)
        if not (self._src.ndim == 1 and self._src.shape == self._dst.shape
                == self._w.shape):
            raise ValueError("DeltaPricer expects flat [S] slot arrays")
        self.stats = {"fast": 0, "propagated": 0, "reanchor": 0}
        self._csr_dirty = True
        self._tau, self._pot, self._crit_arcs, self._eps = self._anchor(
            self._src, self._dst, self._w
        )

    # -- public surface ----------------------------------------------------

    @property
    def tau(self) -> float:
        """Exact max cycle mean of the current graph."""
        return self._tau

    def graph(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(src, dst, w) copies of the current slot arrays."""
        return self._src.copy(), self._dst.copy(), self._w.copy()

    def price(self, slots, src, dst, w, *, force_full: bool = False) -> PricedMove:
        """Price the graph obtained by rewriting ``slots`` to the given
        endpoints/weights (``w = -inf`` empties a slot), without
        committing.  All four are parallel flat arrays.  ``force_full``
        bypasses the certificate and runs the full-Karp oracle (the
        benchmark's baseline arm, and a drift bound for f32 pricers)."""
        slots = np.asarray(slots, dtype=np.int64)
        src2 = np.asarray(src, dtype=np.int64)
        dst2 = np.asarray(dst, dtype=np.int64)
        w2 = np.asarray(w, dtype=self._dtype)
        if force_full:
            return self._price_full(slots, src2, dst2, w2)
        s0, d0, w0 = self._src[slots], self._dst[slots], self._w[slots]
        moved = (s0 != src2) | (d0 != dst2)
        present0 = w0 > NEG_INF
        present2 = w2 > NEG_INF
        weakened = present0 & (moved | (w2 < w0))
        strengthened = present2 & (moved | ~present0 | (w2 > w0))
        crit_hit = self._crit_arcs is None or any(
            (int(a), int(b)) in self._crit_arcs
            for a, b in zip(s0[weakened], d0[weakened])
        )
        if missing_mask(self._tau):
            # Acyclic graph: weakening keeps it acyclic; any strengthened
            # arc may close a cycle — no potentials to reason with.
            if not strengthened.any():
                return PricedMove(self._tau, "fast", slots, src2, dst2, w2,
                                  None, None)
            return self._price_full(slots, src2, dst2, w2)
        if crit_hit and weakened.any():
            return self._price_full(slots, src2, dst2, w2)
        wf = w2.astype(np.float64, copy=False)
        viol = strengthened & (
            self._pot[src2] + wf - self._tau > self._pot[dst2] + self._eps
        )
        if not viol.any():
            return PricedMove(self._tau, "fast", slots, src2, dst2, w2,
                              None, None)
        pot2 = self._propagate(slots, src2, dst2, w2, viol)
        if pot2 is None:  # positive reduced cycle: tau rose
            return self._price_full(slots, src2, dst2, w2)
        return PricedMove(self._tau, "propagated", slots, src2, dst2, w2,
                          pot2, None)

    def commit(self, priced: PricedMove) -> None:
        """Apply a :meth:`price` result to the pricer state."""
        self.stats[priced.kind] += 1
        if ((self._src[priced.slots] != priced.src).any()
                or (self._dst[priced.slots] != priced.dst).any()):
            self._csr_dirty = True
        self._src[priced.slots] = priced.src
        self._dst[priced.slots] = priced.dst
        self._w[priced.slots] = priced.w
        self._tau = priced.tau
        if priced.pot is not None:
            self._pot = priced.pot
        if priced.kind == "reanchor":
            self._crit_arcs = priced.crit_arcs
            scale = max(1.0, abs(priced.tau) if np.isfinite(priced.tau)
                        else 1.0)
            self._eps = (1e-9 if self._dtype.itemsize >= 8 else 1e-4) * scale

    def update(self, slots, src, dst, w) -> float:
        """``price`` + ``commit`` in one call; returns the new tau."""
        priced = self.price(slots, src, dst, w)
        self.commit(priced)
        return priced.tau

    def reanchor(self) -> float:
        """Rebuild the certificate from scratch on the current graph
        (periodic drift bound: under f32 slot weights the fast paths
        carry the anchored tau forward, so a caller can re-anchor every
        K commits to keep accumulated decision error at one oracle call
        of slack).  Returns the re-anchored tau."""
        self._tau, self._pot, self._crit_arcs, self._eps = self._anchor(
            self._src, self._dst, self._w
        )
        self.stats["reanchor"] += 1
        return self._tau

    # -- internals ---------------------------------------------------------

    def _anchor(self, src, dst, w):
        """Full Karp + certificate rebuild on the given arrays (pure —
        does not touch pricer state).  Returns (tau, pot, crit, eps)."""
        N = self.num_nodes
        eb = EdgeBatch(
            src[None].astype(np.int32), dst[None].astype(np.int32),
            w[None], N,
        )
        tau = float(batched_cycle_time_sparse(eb)[0])
        scale = max(1.0, abs(tau) if np.isfinite(tau) else 1.0)
        eps = (1e-9 if self._dtype.itemsize >= 8 else 1e-4) * scale
        if missing_mask(tau):
            pot = np.zeros(N, dtype=np.float64)
            crit: Optional[frozenset] = frozenset()
        else:
            wf = w.astype(np.float64, copy=False)
            present = wf > NEG_INF
            s, d = src[present], dst[present]
            pot = _reduced_potentials(s, d, wf[present] - tau, N, eps)
            _, circuit = critical_circuit_sparse(src, dst, wf, N, tau=tau)
            # Empty circuit on a cyclic graph = numerically degenerate
            # extraction; None = "unknown": every weakening re-anchors.
            crit = (
                frozenset(zip(circuit[:-1], circuit[1:])) if circuit else None
            )
        return tau, pot, crit, eps

    def _price_full(self, slots, src2, dst2, w2) -> PricedMove:
        """Price a proposal with a full Karp pass on the modified graph."""
        ps, pd, pw = self._src.copy(), self._dst.copy(), self._w.copy()
        ps[slots], pd[slots], pw[slots] = src2, dst2, w2
        tau, pot, crit, _ = self._anchor(ps, pd, pw)
        return PricedMove(tau, "reanchor", slots, src2, dst2, w2, pot, crit)

    def _rebuild_csr(self) -> None:
        order = np.argsort(self._src, kind="stable")
        self._csr_slots = order
        self._csr_start = np.searchsorted(
            self._src[order], np.arange(self.num_nodes + 1)
        )
        self._csr_dirty = False

    def _propagate(self, slots, src2, dst2, w2, viol) -> Optional[np.ndarray]:
        """Bounded Bellman repair of the potentials on the proposed graph.

        Returns the repaired potentials, or ``None`` if a vertex updated
        more than N times (a positive reduced cycle: tau increased)."""
        if self._csr_dirty:
            self._rebuild_csr()
        N = self.num_nodes
        tau, eps = self._tau, self._eps
        pot2 = self._pot.copy()
        moved_slots = {int(s): k for k, s in enumerate(slots)}
        wf = w2.astype(np.float64, copy=False)
        frontier: Dict[int, float] = {}
        for k in np.flatnonzero(viol):
            d = int(dst2[k])
            # host numpy throughout: no device sync to batch
            cand = self._pot[int(src2[k])] + float(wf[k]) - tau  # repro-lint: ignore[effect-purity]
            if cand > frontier.get(d, NEG_INF):
                frontier[d] = cand
        counts: Dict[int, int] = {}
        csr_slots, csr_start = self._csr_slots, self._csr_start
        cur_src, cur_dst, cur_w = self._src, self._dst, self._w
        while frontier:
            nxt: Dict[int, float] = {}
            for u, p in frontier.items():
                if p <= pot2[u] + eps:
                    continue
                pot2[u] = p
                c = counts.get(u, 0) + 1
                if c > N:
                    return None
                counts[u] = c
                # out-arcs of u in the *proposed* graph: current CSR rows
                # minus rewritten slots, plus the move's own arcs at u.
                for slot in csr_slots[csr_start[u]:csr_start[u + 1]]:
                    k = moved_slots.get(int(slot))
                    if k is not None:
                        continue
                    wv = float(cur_w[slot])  # repro-lint: ignore[effect-purity]
                    if missing_mask(wv):
                        continue
                    v = int(cur_dst[slot])
                    cand = p + wv - tau
                    if cand > pot2[v] + eps and cand > nxt.get(v, NEG_INF):
                        nxt[v] = cand
                for k, slot in ((k, s) for s, k in moved_slots.items()):
                    if int(src2[k]) != u:
                        continue
                    wv = float(wf[k])  # repro-lint: ignore[effect-purity]
                    if missing_mask(wv):
                        continue
                    v = int(dst2[k])
                    cand = p + wv - tau
                    if cand > pot2[v] + eps and cand > nxt.get(v, NEG_INF):
                        nxt[v] = cand
            frontier = nxt
        return pot2


# ---------------------------------------------------------------------------
# Overlay batches as edge lists (the sparse analogue of
# delays.batched_overlay_delay_matrices)




@span_fn("engine.price_edges")
@contract(None, None, "#E", "[B,E]", ret="eb[B,E+N,N]")
def batched_overlay_delay_edges(gc, tp, arcs: Sequence[Arc], masks) -> EdgeBatch:
    """Eq. 3 delay *edge lists* for a batch of candidate overlays.

    Sparse analogue of
    :func:`repro.core.delays.batched_overlay_delay_matrices`: same
    ``arcs`` pool and ``[B, E]`` boolean ``masks`` selection, but the
    result is an :class:`EdgeBatch` of ``E + N`` slots (the arc pool
    followed by the N computation self-loops) instead of a dense
    ``[B, N, N]`` stack — O(B·(E+N)) memory, never O(B·N²).  Masked-off
    arcs become ``-inf`` padding.  Degrees, and therefore the
    access-link-sharing term of Eq. 3, are recomputed per candidate.
    """
    n = gc.num_silos
    index = {v: k for k, v in enumerate(gc.silos)}
    masks = np.asarray(masks, dtype=bool)
    B, E = masks.shape
    if E != len(arcs):
        raise ValueError(f"masks last dim {E} != number of arcs {len(arcs)}")
    comp = np.array(
        [tp.local_steps * gc.silo_params[v].comp_time_ms for v in gc.silos]
    )
    w = np.empty((B, E + n), dtype=np.float64)
    # self-loop slots: always present
    w[:, E:] = comp[None, :]
    if E == 0:
        loops = np.arange(n, dtype=np.int32)
        src = np.broadcast_to(loops, (B, n))
        return EdgeBatch(src, src, w, n)
    asrc = np.array([index[i] for (i, _) in arcs], dtype=np.int32)
    adst = np.array([index[j] for (_, j) in arcs], dtype=np.int32)
    if np.any(asrc == adst):
        raise ValueError("arc pool must not contain self-loops")
    # The arc layout is identical in every row: broadcast views keep the
    # EdgeBatch contract at O(E) instead of O(B·E) storage.
    loops = np.arange(n, dtype=np.int32)
    src = np.broadcast_to(np.concatenate([asrc, loops]), (B, E + n))
    dst = np.broadcast_to(np.concatenate([adst, loops]), (B, E + n))
    lat = np.array([gc.latency_ms[(i, j)] for (i, j) in arcs])
    bwa = np.array([gc.available_bw_gbps[(i, j)] for (i, j) in arcs])
    up = np.array([gc.silo_params[v].uplink_gbps for v in gc.silos])
    dn = np.array([gc.silo_params[v].downlink_gbps for v in gc.silos])
    # Per-candidate degrees: one matmul against arc-endpoint one-hots
    # (cast first: numpy's bool-times-float matmul path is far slower).
    eye = np.eye(n)
    maskf = masks.astype(np.float64)
    out_deg = maskf @ eye[asrc]  # [B, N]
    # Matching-derived pools interleave both directions of every pair
    # ((i,j) at slot 2p, (j,i) at 2p+1) and activate them together, which
    # makes in-degrees equal out-degrees — skip the second matmul then.
    symmetric = (
        E % 2 == 0
        and np.array_equal(asrc[0::2], adst[1::2])
        and np.array_equal(adst[0::2], asrc[1::2])
        and np.array_equal(masks[:, 0::2], masks[:, 1::2])
    )
    in_deg = out_deg if symmetric else maskf @ eye[adst]
    D = int(max(out_deg.max(), in_deg.max(), 1.0))
    if B > 4 * D * D and D * D * E <= (1 << 24):
        # Degree-table path: Eq. 3 depends on the mask row only through
        # (out_deg[src], in_deg[dst]) ∈ [1, D]², so for large batches of
        # degree-bounded overlays (randomized-schedule pricing: B = rounds
        # × chains) it is far cheaper to tabulate the E × D × D possible
        # arc delays once and gather than to re-derive every [B, E] entry.
        # Same expressions in the same order as the general path below —
        # the results are bit-identical, not approximately equal.
        ds = np.arange(1.0, D + 1.0)
        rate_t = np.minimum(
            (up[asrc] / ds[:, None])[:, None, :],  # out-degree on axis 0
            (dn[adst] / ds[:, None])[None, :, :],  # in-degree on axis 1
        )
        rate_t = np.minimum(rate_t, bwa[None, None, :])
        # table[a-1, b-1, e] = delay of arc e at out_deg=a, in_deg=b
        table = comp[asrc][None, None, :] + lat[None, None, :] + (
            tp.model_size_mbits / rate_t
        )
        oi = np.clip(out_deg.astype(np.int32) - 1, 0, D - 1)[:, asrc]
        if symmetric:
            # ii[:, 2p] == oi[:, 2p+1] and vice versa: an even/odd column
            # swap replaces the second [B, E] index gather outright.
            ii = np.ascontiguousarray(
                oi.reshape(B, E // 2, 2)[:, :, ::-1]
            ).reshape(B, E)
        else:
            ii = np.clip(in_deg.astype(np.int32) - 1, 0, D - 1)[:, adst]
        # flat_idx = (oi·D + ii)·E + e, built in place on oi's buffer;
        # masked-off arcs route through a -inf sentinel slot appended to
        # the table (an in-place copyto instead of a boolean scatter).
        oi *= np.int32(D)
        oi += ii
        oi *= np.int32(E)
        oi += np.arange(E, dtype=np.int32)
        np.copyto(oi, np.int32(D * D * E), where=~masks)
        tflat = np.append(table.ravel(), NEG_INF)
        w[:, :E] = tflat.take(oi)
        return EdgeBatch(src, dst, w, n)
    rate = np.minimum(
        up[asrc][None, :] / np.maximum(out_deg[:, asrc], 1.0),
        dn[adst][None, :] / np.maximum(in_deg[:, adst], 1.0),
    )
    rate = np.minimum(rate, bwa[None, :])
    w[:, :E] = np.where(
        masks, comp[asrc][None, :] + lat[None, :] + tp.model_size_mbits / rate, NEG_INF
    )
    return EdgeBatch(src, dst, w, n)
