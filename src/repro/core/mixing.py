"""Batched mixing-rate pricing: the convergence half of co-design.

The paper's evaluation (Sect. 4) ranks topologies on *time-to-ε*, yet
cycle time τ (Eq. 4) only prices the throughput half: a sparse ring
wins rounds-per-second while mixing information at 1 − O(1/N²) per
round, and MATCHA's whole point — mixing per unit of traffic — is
invisible to τ̄.  This module prices the other half on the engine's
batched layouts:

* **Consensus matrices** from edge activations: :func:`mixing_matrix`
  (single) and :func:`batched_mixing_matrices` (``[B, E]`` activation
  masks over a shared arc pool → ``[B, N, N]`` stacks) under the
  local-degree rule the runtime deploys
  (:func:`repro.core.consensus.local_degree_matrix`, the matrix
  :class:`repro.fed.gossip.ScheduleSlot` builds each round), plus
  Metropolis and uniform (max-degree) weights.
* **Contraction factor ρ**: :func:`batched_rho` — the second-largest
  singular value of W, i.e. ``‖W − (1/n)·11ᵀ‖₂`` — over a whole
  candidate stack in one LAPACK call (``eigvalsh`` fast path for
  symmetric stacks, ``svd`` in general), with a jittable
  ``lax.linalg``-backed twin :func:`batched_rho_jax`.
* **Randomized schedules**: the per-round matrix is a random variable,
  so the right contraction is ``ρ² = λ_max(E[WᵀW] − (1/n)·11ᵀ)``
  (E‖x_{k+1} − x̄‖² ≤ ρ²·E‖x_k − x̄‖²).  :func:`matcha_expected_gram`
  estimates E[WᵀW] from the *same* bulk-drawn activation masks the
  Monte-Carlo τ̄ pricing consumes
  (:meth:`repro.core.schedule.MatchaSchedule.activation_masks`),
  deduplicating repeated activation subsets so only the distinct
  matrices are built.
* **The composite objective**: :func:`wall_clock_to_eps` scores a
  ``(τ, ρ)`` pair as ``τ / −log(ρ)`` — milliseconds per e-fold of
  consensus-error decay, the wall-clock-to-ε framing of Sect. 4 — and
  :func:`pareto_frontier` returns the non-dominated candidates for
  callers that want the whole tradeoff curve rather than one scalar.

Everything here is pure numpy over label-indexed graphs; jax is only
imported lazily inside the ``*_jax`` twins (jax-free hosts can price
mixing).  All ρ math is f64 by default but dtype-preserving: f32 stacks
price in f32 (the property tests pin both).
"""

from __future__ import annotations

import math
from typing import Hashable, List, Optional, Sequence, Tuple

import numpy as np

from ..analysis.contracts import contract
from ..obs.spans import span_fn
from .consensus import local_degree_matrix, metropolis_matrix, ring_matrix
from .schedule import Schedule, _unique_rows

Node = Hashable
Edge = Tuple[Node, Node]

#: Supported consensus-weight rules for matrix construction.
WEIGHT_RULES = ("local_degree", "metropolis", "uniform")

#: Supported design objectives (ControllerConfig.objective / --objective).
OBJECTIVES = ("tau", "time_to_eps")

#: Floor applied to ρ inside the −log: a perfectly-mixing round (ρ = 0,
#: e.g. STAR's full averaging) still costs one round, so its score must
#: stay proportional to τ rather than collapsing to zero.
RHO_FLOOR = 1e-9


# ---------------------------------------------------------------------------
# Consensus-matrix construction


@contract("N", "#E", ret="[N,N]")
def mixing_matrix(
    num_nodes: int,
    edges: Sequence[Tuple[int, int]],
    *,
    rule: str = "local_degree",
) -> np.ndarray:
    """Consensus matrix of one directed edge list (0-based indices).

    ``rule`` picks the weight scheme: ``"local_degree"`` (Eq. 22-23,
    what the gossip runtime deploys), ``"metropolis"``
    (Metropolis-Hastings, symmetrized support) or ``"uniform"``
    (constant weight ``1/(1+Δ)`` with Δ the max degree).  Undirected
    overlays must list both arc directions, as everywhere in the repo.
    """
    n = int(num_nodes)
    if rule == "local_degree":
        return local_degree_matrix(n, edges)
    if rule == "metropolis":
        return metropolis_matrix(n, edges)
    if rule == "uniform":
        deg = np.zeros(n, dtype=np.int64)
        for (i, j) in edges:
            if i != j:
                deg[j] += 1
        alpha = 1.0 / (1.0 + (int(deg.max()) if n else 0))
        A = np.zeros((n, n), dtype=np.float64)
        for (i, j) in edges:
            if i != j:
                A[j, i] = alpha
        A = np.maximum(A, A.T)  # symmetrize support
        for i in range(n):
            A[i, i] = 1.0 - A[i].sum()
        return A
    raise ValueError(f"unknown weight rule {rule!r}; one of {WEIGHT_RULES}")


@span_fn("engine.mixing_matrices")
@contract("N", "[E]", "[E]", "[B,E]", ret="[B,N,N]")
def batched_mixing_matrices(
    num_nodes: int,
    src: np.ndarray,
    dst: np.ndarray,
    masks: np.ndarray,
    *,
    rule: str = "local_degree",
) -> np.ndarray:
    """``[B, N, N]`` consensus matrices of ``[B, E]`` arc activations.

    ``src``/``dst`` are the shared directed arc pool (0-based node
    indices; both directions present for undirected links), ``masks``
    the per-candidate activation — the same layout the sparse max-plus
    engine prices τ on, so one mask stack feeds both halves of the
    (τ, ρ) pair.  Degrees are recomputed per row (a deactivated arc
    changes its endpoints' weights), fully vectorized: one ``bincount``
    for the ``[B, N]`` degree table and one scatter-add for the
    off-diagonal entries.  A row with no active arcs yields the
    identity (no mixing, ρ = 1).
    """
    n = int(num_nodes)
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    act = np.asarray(masks, dtype=np.float64)
    if rule not in WEIGHT_RULES:
        raise ValueError(f"unknown weight rule {rule!r}; one of {WEIGHT_RULES}")
    B, E = act.shape
    A = np.zeros((B, n, n), dtype=np.float64)
    di = np.arange(n, dtype=np.int64)
    if E == 0:
        A[:, di, di] = 1.0
        return A
    act = np.where(src[None, :] == dst[None, :], 0.0, act)  # drop self-loops
    flat = (np.arange(B, dtype=np.int64)[:, None] * n + dst[None, :]).ravel()
    deg = np.bincount(flat, weights=act.ravel(), minlength=B * n).reshape(B, n)
    if rule == "uniform":
        w = act / (1.0 + deg.max(axis=1, keepdims=True))
    else:  # local_degree / metropolis share the pairwise max-degree weight
        w = act / (1.0 + np.maximum(deg[:, src], deg[:, dst]))
    rows = np.broadcast_to(np.arange(B, dtype=np.int64)[:, None], (B, E))
    np.add.at(
        A,
        (rows, np.broadcast_to(dst, (B, E)), np.broadcast_to(src, (B, E))),
        w,
    )
    if rule in ("metropolis", "uniform"):
        A = np.maximum(A, np.transpose(A, (0, 2, 1)))  # symmetrize support
    A[:, di, di] = 0.0
    A[:, di, di] = 1.0 - A.sum(axis=2)
    return A


# ---------------------------------------------------------------------------
# Batched contraction factor / spectral gap


@span_fn("engine.mixing_rho")
@contract("[B,N,N]", ret="[B]")
def batched_rho(W: np.ndarray, *, symmetric: bool = False) -> np.ndarray:
    """``[B]`` contraction factors ρ = ‖W − (1/n)·11ᵀ‖₂ of a matrix stack.

    For doubly-stochastic W this is the second-largest singular value —
    the per-round worst-case consensus contraction (‖Wx − x̄‖ ≤
    ρ·‖x − x̄‖ for mean-zero deviations).  ``symmetric=True`` takes the
    ``eigvalsh`` fast path (ρ = max |λ| of the deflated matrix), valid
    for symmetric stacks (local-degree/Metropolis on undirected
    overlays); the default prices arbitrary (e.g. directed-ring) stacks
    via one batched SVD.  dtype-preserving: a float32 stack is priced
    in float32.
    """
    W = np.asarray(W)
    n = W.shape[-1]
    M = W - np.asarray(1.0 / n, dtype=W.dtype)
    if symmetric:
        lam = np.linalg.eigvalsh(0.5 * (M + np.swapaxes(M, -1, -2)))
        return np.maximum(np.abs(lam[..., 0]), np.abs(lam[..., -1]))
    s = np.linalg.svd(M, compute_uv=False)
    return s[..., 0]


@span_fn("engine.mixing_gap")
@contract("[B,N,N]", ret="[B]")
def batched_spectral_gap(W: np.ndarray, *, symmetric: bool = False) -> np.ndarray:
    """``[B]`` spectral gaps ``1 − ρ`` (see :func:`batched_rho`); the
    batched twin of :func:`repro.core.consensus.spectral_gap`."""
    one = np.asarray(1.0, dtype=np.asarray(W).dtype)
    return one - batched_rho(W, symmetric=symmetric)


@span_fn("engine.mixing_rho_jax")
@contract("[B,N,N]", ret="[B]")
def batched_rho_jax(W) -> "np.ndarray":
    """Jittable JAX twin of :func:`batched_rho` (general SVD path).

    Wrap in ``jax.jit`` at the call site to cache compilation per
    (B, N); the body is pure ``jnp``/``lax.linalg`` so it vmaps and
    fuses into surrounding device code.  dtype follows the input (note
    jax defaults to f32 unless x64 is enabled).
    """
    import jax.numpy as jnp

    W = jnp.asarray(W)
    n = W.shape[-1]
    M = W - jnp.asarray(1.0 / n, dtype=W.dtype)
    s = jnp.linalg.svd(M, compute_uv=False)
    return s[..., 0]


@span_fn("engine.mixing_gap_jax")
@contract("[B,N,N]", ret="[B]")
def batched_spectral_gap_jax(W) -> "np.ndarray":
    """Jittable JAX twin of :func:`batched_spectral_gap`."""
    import jax.numpy as jnp

    W = jnp.asarray(W)
    return jnp.asarray(1.0, dtype=W.dtype) - batched_rho_jax(W)


# ---------------------------------------------------------------------------
# Overlay / plan / schedule pricing


def _silo_index(
    n: int, silos: Optional[Sequence[Node]], edges: Sequence[Edge]
) -> dict:
    if silos is None:
        labels = {v for e in edges for v in e}
        try:
            silos = sorted(labels)
        except TypeError:
            silos = sorted(labels, key=repr)
    return {v: k for k, v in enumerate(silos)}


@contract(None, "N", ret="[N,N]")
def overlay_mixing_matrix(
    overlay, num_nodes: int, *, silos: Optional[Sequence[Node]] = None
) -> np.ndarray:
    """The consensus matrix the runtime would deploy for ``overlay``.

    Mirrors :func:`repro.fed.topology_runtime.plan_from_overlay` exactly
    (ring-named overlays get the Appendix H.4 optimal ``(I + P)/2``,
    STAR gets full averaging ``(1/n)·11ᵀ``, everything else the
    local-degree rule) so the priced ρ is the deployed ρ — but lives in
    ``core`` with no jax import, so designers can price mixing on
    jax-free hosts.  ``silos`` pins the label → index order (pass
    ``gc.silos``); by default edge labels are sorted.
    """
    n = int(num_nodes)
    index = _silo_index(n, silos, overlay.edges)
    edges = [(index[i], index[j]) for (i, j) in overlay.edges]
    if overlay.name.startswith("ring") and edges:
        nxt = {i: j for (i, j) in edges}
        if len(nxt) == n == len(edges):
            tour = [edges[0][0]]
            for _ in range(n - 1):
                tour.append(nxt[tour[-1]])
            return ring_matrix(n, tour)
        # ring-named but not a single directed tour (e.g. a repaired
        # ring fragment): fall through to the local-degree rule, which
        # is what plan construction would reject and re-derive anyway.
    if overlay.name == "star":
        return np.full((n, n), 1.0 / n, dtype=np.float64)
    return local_degree_matrix(n, edges)


@span_fn("engine.overlay_rho")
@contract(None, "N", ret="[]")
def overlay_rho(
    overlay, num_nodes: int, *, silos: Optional[Sequence[Node]] = None
) -> float:
    """ρ of one overlay's deployed consensus matrix."""
    W = overlay_mixing_matrix(overlay, num_nodes, silos=silos)
    return float(batched_rho(W[None])[0])


@span_fn("engine.overlay_rho_batch")
@contract("#C", "N", ret="[C]")
def overlay_rho_batch(
    overlays: Sequence, num_nodes: int, *, silos: Optional[Sequence[Node]] = None
) -> np.ndarray:
    """``[len(overlays)]`` ρ of a candidate pool in one batched SVD.

    Matrix construction is per-overlay (rules differ: ring vs star vs
    local-degree) but the spectral pricing — the O(N³) part — is one
    stacked LAPACK call, the same batching win as the max-plus engines.
    """
    if not len(overlays):
        return np.zeros((0,), dtype=np.float64)
    W = np.stack(
        [
            overlay_mixing_matrix(ov, num_nodes, silos=silos)
            for ov in overlays
        ]
    )
    return batched_rho(W)


@span_fn("engine.matcha_expected_gram")
@contract(None, None, ret="[N,N]")
def matcha_expected_gram(
    schedule,
    gc,
    *,
    rounds: int = 128,
    seed: int = 0,
    rule: str = "local_degree",
) -> np.ndarray:
    """Empirical ``E[WᵀW]`` of a randomized schedule's per-round matrix.

    Draws ``rounds`` activation rows from the schedule's own bulk
    sampler (:meth:`~repro.core.schedule.MatchaSchedule.activation_masks`
    — the stream τ̄ pricing consumes), deduplicates repeated activation
    subsets (at small budgets most rounds repeat a handful), builds the
    distinct consensus matrices in one :func:`batched_mixing_matrices`
    call under ``rule`` (``"local_degree"`` matches what
    :class:`repro.fed.gossip.ScheduleSlot` deploys per round) and
    returns the count-weighted Gram average.  The arc pool is filtered
    to pairs ``gc`` still routes, exactly as τ̄ pricing filters it.
    """
    arcs, mids = schedule._arc_pool(gc)
    if not arcs:
        # Nothing routable: every round is the identity (no mixing).
        return np.eye(gc.num_silos, dtype=np.float64)
    index = {v: k for k, v in enumerate(gc.silos)}
    src = np.asarray([index[i] for (i, _) in arcs], dtype=np.int64)
    dst = np.asarray([index[j] for (_, j) in arcs], dtype=np.int64)
    masks = schedule.activation_masks(rounds, seed)  # [R, M]
    first, inv = _unique_rows(masks)
    counts = np.bincount(inv, minlength=len(first)).astype(np.float64)
    p = counts / counts.sum()
    uniq = masks[first][:, mids]  # [U, E] arc activations
    W = batched_mixing_matrices(gc.num_silos, src, dst, uniq, rule=rule)
    return np.einsum("u,uij,uik->jk", p, W, W)


@contract("[N,N]", ret="[]")
def contraction_from_gram(G: np.ndarray) -> float:
    """ρ = sqrt(λ_max(E[WᵀW] − (1/n)·11ᵀ)) of a symmetric Gram average —
    the mean-square per-round consensus contraction of a random W."""
    G = np.asarray(G, dtype=np.float64)
    n = G.shape[0]
    M = G - np.full((n, n), 1.0 / n, dtype=np.float64)
    lam = float(np.linalg.eigvalsh(0.5 * (M + M.T))[-1])
    return float(math.sqrt(max(lam, 0.0)))


@span_fn("engine.schedule_rho")
@contract(None, None, ret="[]")
def schedule_rho(
    schedule: Schedule,
    gc,
    *,
    rounds: int = 128,
    seed: int = 0,
    rule: str = "local_degree",
) -> float:
    """ρ of any :class:`~repro.core.schedule.Schedule` on an estimate.

    Fixed schedules price the deployed overlay matrix exactly
    (:func:`overlay_rho`); randomized ones price the expected
    contraction ``sqrt(λ_max(E[WᵀW] − J/n))`` over ``rounds`` sampled
    activation rows (:func:`matcha_expected_gram`).
    """
    if not schedule.is_randomized:
        return overlay_rho(
            schedule.overlay, gc.num_silos, silos=tuple(gc.silos)
        )
    G = matcha_expected_gram(schedule, gc, rounds=rounds, seed=seed, rule=rule)
    return contraction_from_gram(G)


# ---------------------------------------------------------------------------
# The composite objective and the Pareto frontier


@contract(ret="[]")
def wall_clock_to_eps(tau_ms: float, rho: float) -> float:
    """Score a ``(τ, ρ)`` pair as wall clock per e-fold of error decay.

    Consensus error contracts by ρ per round, so reaching a target ε
    takes ``log(1/ε)/(−log ρ)`` rounds at τ ms each — the Sect. 4
    time-to-ε framing up to the ε-dependent constant, which cancels in
    any argmin.  ``ρ ≥ 1`` (disconnected / no contraction) scores +inf;
    ρ is floored at :data:`RHO_FLOOR` so perfectly-mixing one-round
    topologies (STAR) stay proportional to their τ instead of scoring
    an impossible zero.  NaN ρ propagates (the caller forgot to price
    mixing).
    """
    tau = float(tau_ms)
    r = float(rho)
    if math.isnan(r):
        return float("nan")
    if r >= 1.0:
        return float("inf")
    return tau / -math.log(max(r, RHO_FLOOR))


@contract(None, ret="[]")
def score_estimate(est, objective: str) -> float:
    """Scalarize a priced estimate under ``objective``.

    ``est`` is any object with ``tau_ms`` and ``rho`` attributes
    (:class:`~repro.core.schedule.ScheduleEstimate`).  ``"tau"`` ranks
    on cycle time alone (the paper's Table 1 regime); ``"time_to_eps"``
    on :func:`wall_clock_to_eps` and raises if ρ was never priced —
    silently ranking NaNs would make ``min()`` nondeterministic.
    """
    if objective == "tau":
        return float(est.tau_ms)
    if objective == "time_to_eps":
        score = wall_clock_to_eps(est.tau_ms, est.rho)
        if math.isnan(score):
            raise ValueError(
                "objective='time_to_eps' needs a priced rho; this "
                "estimate has rho=NaN (price mixing before scoring)"
            )
        return score
    raise ValueError(f"unknown objective {objective!r}; one of {OBJECTIVES}")


@contract("[C]", "[C]", ret=None)
def pareto_frontier(taus, rhos) -> np.ndarray:
    """Indices of the (τ, ρ)-non-dominated candidates, sorted by τ.

    A candidate is dominated when another is at least as fast *and*
    mixes at least as well, strictly better in one.  The frontier is
    what a designer should surface when the caller wants the tradeoff
    curve instead of one scalarized pick: every point on it is optimal
    for *some* convergence/throughput weighting.
    """
    t = np.asarray(taus, dtype=np.float64)
    r = np.asarray(rhos, dtype=np.float64)
    order = np.lexsort((r, t))  # by τ, ties by ρ
    keep: List[int] = []
    best_r = np.inf
    for k in order:
        if r[k] < best_r:
            keep.append(int(k))
            best_r = r[k]
    return np.asarray(keep, dtype=np.int64)
