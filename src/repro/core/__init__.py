"""Core contribution of the paper: max-plus throughput analysis and
throughput-optimal topology design for cross-silo federated learning.

Three generations of the max-plus machinery coexist, equivalence-tested
against each other (see docs/architecture.md for the full map):

* :mod:`repro.core.maxplus`        — node-labelled dict front end +
  ``*_legacy`` pure-Python oracles;
* :mod:`repro.core.maxplus_vec`    — dense batched ``[B, N, N]`` engine
  (numpy f32/f64 + jittable JAX);
* :mod:`repro.core.maxplus_sparse` — padded edge-list ``[B, E]`` engine
  for large sparse overlays, powering the device-side
  :func:`~repro.core.topologies.search_overlays_jit`.
"""

from .maxplus import (
    DelayDigraph,
    cycle_time,
    throughput,
    max_cycle_mean,
    max_cycle_mean_legacy,
    timing_recursion,
    timing_recursion_legacy,
    empirical_cycle_time,
    critical_circuit,
    critical_circuit_legacy,
    is_strongly_connected,
    strongly_connected_components,
)
from .maxplus_vec import (
    NEG_INF,
    missing_mask,
    batched_cycle_time,
    batched_cycle_time_jax,
    batched_is_strongly_connected,
    batched_throughput,
    batched_timing_recursion,
    batched_timing_recursion_piecewise,
    critical_circuit_dense,
    cycle_time_dense,
    edges_to_matrix,
    graph_to_matrix,
    reachability_closure,
    scc_labels,
    timing_recursion_dense,
    timing_recursion_piecewise,
)
from .maxplus_sparse import (
    EdgeBatch,
    batched_cycle_time_sparse,
    batched_cycle_time_sparse_jax,
    batched_is_strongly_connected_sparse,
    batched_overlay_delay_edges,
    batched_timing_recursion_sparse,
    critical_circuit_sparse,
    cycle_time_sparse,
    dense_to_edge_batch,
    edge_batch_to_dense,
    reachable_from_sparse,
    scc_labels_sparse,
    timing_recursion_time_varying_sparse,
    timing_recursion_time_varying_sparse_jax,
    timing_recursion_unique_rounds_sparse,
)
from .delays import (
    ConnectivityGraph,
    SiloParams,
    TrainingParams,
    edge_delay_ms,
    connectivity_delay_ms,
    symmetrized_delay_ms,
    overlay_delay_digraph,
    overlay_delay_matrix,
    batched_overlay_delay_matrices,
    is_edge_capacitated,
)
from .underlay import Underlay, haversine_km, link_latency_ms
from .networks_data import (
    GAIA_SITES,
    make_underlay,
    NETWORK_NAMES,
    EXPECTED_SIZES,
    WORKLOADS,
)
from .topologies import (
    Overlay,
    design_overlay,
    design_schedule,
    SCHEDULE_KINDS,
    star_overlay,
    mst_overlay,
    ring_overlay,
    two_opt_ring_overlay,
    algorithm1_mbst,
    delta_prim,
    christofides_tour,
    brute_force_mct,
    evaluate_overlay,
    search_overlays_jit,
    search_overlays_delta,
    search_overlays_hierarchical,
    cluster_silos,
    OVERLAY_KINDS,
)
from .matcha import Matcha, matcha_from_connectivity, matcha_plus_from_underlay, greedy_edge_coloring
from .schedule import (
    DEFAULT_MATCHA_BUDGETS,
    FixedSchedule,
    MatchaSchedule,
    Schedule,
    ScheduleEstimate,
    ScheduleInfeasibleError,
    average_cycle_times_batched,
    design_matcha_schedule,
    matcha_schedule_from_connectivity,
    matcha_schedule_from_underlay,
    schedule_from_matcha,
)
from .consensus import (
    local_degree_matrix,
    ring_matrix,
    metropolis_matrix,
    star_matrix,
    is_doubly_stochastic,
    spectral_gap,
)
from .mixing import (
    OBJECTIVES,
    WEIGHT_RULES,
    batched_mixing_matrices,
    batched_rho,
    batched_rho_jax,
    batched_spectral_gap,
    batched_spectral_gap_jax,
    contraction_from_gram,
    matcha_expected_gram,
    mixing_matrix,
    overlay_mixing_matrix,
    overlay_rho,
    overlay_rho_batch,
    pareto_frontier,
    schedule_rho,
    score_estimate,
    wall_clock_to_eps,
)
from .birkhoff import birkhoff_decomposition, reconstruct, schedule_cost
from .simulator import (
    Timeline,
    simulate_overlay,
    simulate_overlays_batched,
    predicted_cycle_time,
    training_time_ms,
)
