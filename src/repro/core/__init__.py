"""Core contribution of the paper: max-plus throughput analysis and
throughput-optimal topology design for cross-silo federated learning."""

from .maxplus import (
    DelayDigraph,
    cycle_time,
    throughput,
    max_cycle_mean,
    timing_recursion,
    empirical_cycle_time,
    critical_circuit,
    is_strongly_connected,
    strongly_connected_components,
)
from .delays import (
    ConnectivityGraph,
    SiloParams,
    TrainingParams,
    edge_delay_ms,
    connectivity_delay_ms,
    symmetrized_delay_ms,
    overlay_delay_digraph,
    is_edge_capacitated,
)
from .underlay import Underlay, haversine_km, link_latency_ms
from .networks_data import make_underlay, NETWORK_NAMES, EXPECTED_SIZES, WORKLOADS
from .topologies import (
    Overlay,
    design_overlay,
    star_overlay,
    mst_overlay,
    ring_overlay,
    two_opt_ring_overlay,
    algorithm1_mbst,
    delta_prim,
    christofides_tour,
    brute_force_mct,
    evaluate_overlay,
    OVERLAY_KINDS,
)
from .matcha import Matcha, matcha_from_connectivity, matcha_plus_from_underlay, greedy_edge_coloring
from .consensus import (
    local_degree_matrix,
    ring_matrix,
    metropolis_matrix,
    star_matrix,
    is_doubly_stochastic,
    spectral_gap,
)
from .birkhoff import birkhoff_decomposition, reconstruct, schedule_cost
from .simulator import Timeline, simulate_overlay, predicted_cycle_time, training_time_ms
