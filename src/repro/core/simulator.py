"""Time simulator (Algorithm 3, Appendix F).

Reconstructs the wall-clock instants ``t_i(k)`` at which every silo starts
its k-th computation phase, for a fixed overlay, directly from the
max-plus recursion with the Eq. 3 delays.  The asymptotic slope of
``t_i(k)`` is the cycle time — cross-validated in tests against Karp's
algorithm (the paper's key theoretical identity, Thm 3.23 of [6]).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Hashable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .delays import ConnectivityGraph, TrainingParams, overlay_delay_matrix
from .maxplus_vec import (
    batched_timing_recursion,
    cycle_time_dense,
    timing_recursion_dense,
)

Node = Hashable


@dataclass
class Timeline:
    """t[i][k] = time silo i starts computing w_i((s+1)k + 1)."""

    times: Dict[Node, List[float]]
    num_rounds: int

    def finish_time(self, k: Optional[int] = None) -> float:
        k = self.num_rounds if k is None else k
        return max(series[k] for series in self.times.values())

    def empirical_cycle_time(self) -> float:
        k0, k1 = self.num_rounds // 2, self.num_rounds
        return max(
            (s[k1] - s[k0]) / (k1 - k0) for s in self.times.values()
        )

    def rounds_completed_by(self, t_ms: float) -> int:
        """Max k such that every silo has started round k by time t."""
        k = 0
        while k < self.num_rounds and self.finish_time(k + 1) <= t_ms:
            k += 1
        return k


def simulate_overlay(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    overlay_edges: Sequence[Tuple[Node, Node]],
    num_rounds: int = 100,
) -> Timeline:
    """Run Eq. 4 as a dense ``[N]``-state vector recursion (one
    ``np.max`` sweep per round) and repackage per-silo series."""
    W = overlay_delay_matrix(gc, tp, overlay_edges)
    series = timing_recursion_dense(W, num_rounds)  # [R+1, N]
    times = {v: series[:, k].tolist() for k, v in enumerate(gc.silos)}
    return Timeline(times=times, num_rounds=num_rounds)


def simulate_overlays_batched(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    overlays: Sequence[Sequence[Tuple[Node, Node]]],
    num_rounds: int = 100,
) -> np.ndarray:
    """Timelines for many candidate overlays in one engine call.

    Returns ``[B, num_rounds + 1, N]`` start times (silo order =
    ``gc.silos``) — the bulk companion of :func:`simulate_overlay` for
    scenario sweeps.
    """
    W = np.stack([overlay_delay_matrix(gc, tp, e) for e in overlays])
    return batched_timing_recursion(W, num_rounds)


def predicted_cycle_time(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    overlay_edges: Sequence[Tuple[Node, Node]],
) -> float:
    """Cycle time of an overlay straight from its measured inputs: build
    the Eq. 3 delay matrix and take the max cycle mean (Eq. 5).  The
    scalar the designers minimize and the simulator's slope converges
    to."""
    return cycle_time_dense(overlay_delay_matrix(gc, tp, overlay_edges))


def training_time_ms(
    gc: ConnectivityGraph,
    tp: TrainingParams,
    overlay_edges: Sequence[Tuple[Node, Node]],
    rounds_to_target: int,
) -> float:
    """Wall-clock time for ``rounds_to_target`` communication rounds — the
    product the paper optimizes (cycle time x rounds, Sect. 4)."""
    tl = simulate_overlay(gc, tp, overlay_edges, num_rounds=rounds_to_target)
    return tl.finish_time(rounds_to_target)
