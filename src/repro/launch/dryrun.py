import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers and compiles on the production mesh, and extract the
roofline terms from the compiled artifact.

MUST be run as a module entry point (never imported by tests — the
XLA_FLAGS line above forces 512 host devices before jax initializes):

    PYTHONPATH=src python -m repro.launch.dryrun --arch mistral-large-123b \
        --shape train_4k [--multi-pod] [--gossip ring]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, shape_supported
from repro.launch.mesh import make_production_mesh, mesh_context, CHIPS_PER_POD
from repro.launch import input_specs as IS
from repro.launch.steps import build_train_step, build_prefill_step, build_decode_step
from repro.launch.hlo_analysis import (
    make_roofline,
    model_flops_estimate,
    collective_bytes,
)
from repro.launch.analytic_model import analytic_step_flops
from repro.models import count_params
from repro.models import transformer as T
from repro.models.act_sharding import activation_sharding
from repro.optim import adamw

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def active_param_count(cfg) -> float:
    """Parameters touched per token: full count minus routed experts not in
    the top-k (MoE 6*N_active*D convention)."""
    specs = T.model_specs(cfg)
    total = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "axes")
    )[0]:
        keys = [getattr(p, "key", None) for p in path]
        n = float(np.prod(leaf.shape))
        if cfg.moe is not None and "moe" in keys and any(
            k in ("w_gate", "w_up", "w_down") for k in keys
        ):
            n *= cfg.moe.top_k / cfg.moe.n_experts
        total += n
    return total


def _mem_analysis(compiled) -> Dict[str, Any]:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            if hasattr(ma, k):
                out[k] = int(getattr(ma, k))
        out["peak_bytes_per_device"] = int(
            out.get("argument_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
        )
    except Exception as e:  # pragma: no cover
        out["error"] = str(e)
        out["peak_bytes_per_device"] = 0
    return out


def dryrun_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    gossip: str = "ring",
    local_steps: int = 1,
    save: bool = True,
    verbose: bool = True,
    config_overrides: Optional[Dict[str, Any]] = None,
    tag: str = "",
) -> Dict[str, Any]:
    t0 = time.time()
    spec = INPUT_SHAPES[shape_name]
    kind = spec["kind"]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = int(np.prod(mesh.devices.shape))

    overrides = dict(config_overrides or {})
    n_silos = 2 if (multi_pod and kind == "train") else 1
    overrides.setdefault("n_silos", n_silos)
    # Unrolled attention scans make cost_analysis exact but (a) slow
    # compiles and (b) keep many live fp32 score buffers at 32k prefill.
    # Unroll only single-pod train shapes (small per-microbatch blocks);
    # prefill/decode/multi-pod rely on the analytic FLOP cross-check.
    # unroll inflates compile time ~linearly with layers; for the 52-88
    # layer giants rely on the analytic FLOP cross-check instead
    overrides.setdefault(
        "analysis_unroll",
        (not multi_pod) and kind == "train"
        and get_config(arch).n_layers <= 48)
    # NOTE: flash_vjp / banded_swa stay OFF here — the sweep records the
    # paper-faithful/naive BASELINE; §Perf runs opt in via overrides.
    overrides.setdefault("flash_vjp", False)
    cfg = get_config(arch, **overrides)
    if not shape_supported(cfg, shape_name):
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                  "status": "skipped", "reason": "full attention: long_500k "
                  "requires sub-quadratic decode (DESIGN.md §4)"}
        if save:
            os.makedirs(RESULTS_DIR, exist_ok=True)
            fn = f"{arch}_{shape_name}_{mesh_name.replace('x','-')}.json"
            with open(os.path.join(RESULTS_DIR, fn), "w") as f:
                json.dump(result, f, indent=2)
        return result

    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "gossip": gossip if kind == "train" else None, "status": "?",
    }
    try:
        if kind == "train":
            per_silo_batch = spec["global_batch"] // max(cfg.n_silos, 1)
            accum = max(1, per_silo_batch // 16)
            batch = IS.train_input_specs(cfg, shape_name,
                                         local_steps=local_steps,
                                         accum_steps=accum)
            batch_ps = IS.train_batch_pspecs(cfg, batch, multi_pod=multi_pod,
                                             accum_steps=accum)
            params_abs = IS.abstract_model_params(cfg, jnp.bfloat16)
            params_ps = IS.model_param_pspecs(cfg, multi_pod_training=multi_pod)
            opt = adamw(1e-4)
            from repro.fed.topology_runtime import plan_for_n_silos

            plan = plan_for_n_silos(gossip, cfg.n_silos) if cfg.n_silos > 1 else None
            # grads constrained to the per-tensor param specs (without the
            # leading silo dim — the vmap adds it back)
            from repro.models import FSDP_TP
            from repro.models.params import param_pspecs as _pps

            grad_ps = _pps(T.model_specs(cfg), FSDP_TP)
            step_fn = build_train_step(
                cfg, optimizer=opt, gossip_impl="ppermute", silo_axis="pod",
                plan=plan, mesh=mesh, local_steps=local_steps,
                accum_steps=accum, grad_pspecs=grad_ps,
            )
            opt_abs = jax.eval_shape(
                opt.init if cfg.n_silos == 1 else jax.vmap(opt.init), params_abs)
            opt_ps = jax.tree_util.tree_map(
                lambda _: None, opt_abs) if not jax.tree_util.tree_leaves(opt_abs) else {
                "mu": params_ps, "nu": params_ps}
            state_abs = {"params": params_abs, "opt_state": opt_abs,
                         "step": jax.ShapeDtypeStruct((), jnp.int32)}
            state_ps = {"params": params_ps, "opt_state": opt_ps, "step": P()}
            state_sh = IS.named(state_ps, mesh)
            batch_sh = IS.named(batch_ps, mesh)
            with mesh_context(mesh), activation_sharding(("data",)):
                lowered = jax.jit(
                    step_fn,
                    in_shardings=(state_sh, batch_sh),
                    out_shardings=(state_sh, None),
                ).lower(state_abs, batch)
                compiled = lowered.compile()
        elif kind == "prefill":
            batch = IS.serve_input_specs(cfg, shape_name)
            batch_ps = IS.serve_batch_pspecs(cfg, batch, mesh)
            params_abs = IS.abstract_model_params(cfg, jnp.bfloat16)
            params_ps = IS.model_param_pspecs(cfg)
            step_fn = build_prefill_step(cfg, max_len=spec["seq_len"])
            B = spec["global_batch"]
            batch_axes = (("pod", "data") if (multi_pod and B >= 32)
                          else ("data",) if B >= 16 else None)
            with mesh_context(mesh), activation_sharding(batch_axes):
                lowered = jax.jit(
                    step_fn,
                    in_shardings=(IS.named(params_ps, mesh), IS.named(batch_ps, mesh)),
                ).lower(params_abs, batch)
                compiled = lowered.compile()
        else:  # decode
            batch = IS.serve_input_specs(cfg, shape_name)
            batch_ps = IS.serve_batch_pspecs(cfg, batch, mesh)
            params_abs = IS.abstract_model_params(cfg, jnp.bfloat16)
            params_ps = IS.model_param_pspecs(cfg)
            step_fn = build_decode_step(cfg)
            B = spec["global_batch"]
            batch_axes = (("pod", "data") if (multi_pod and B >= 32)
                          else ("data",) if B >= 16 else None)
            with mesh_context(mesh), activation_sharding(batch_axes):
                lowered = jax.jit(
                    step_fn,
                    in_shardings=(IS.named(params_ps, mesh), IS.named(batch_ps, mesh)),
                    out_shardings=(None, IS.named(batch_ps["cache"], mesh)),
                ).lower(params_abs, batch)
                compiled = lowered.compile()

        cost = dict(compiled.cost_analysis() or {})
        mem = _mem_analysis(compiled)
        hlo = compiled.as_text()
        n_active = active_param_count(cfg)
        mf = model_flops_estimate(cfg, spec, n_active, kind)
        scale = (local_steps * accum) if kind == "train" else 1.0
        roof = make_roofline(
            arch=arch, shape=shape_name, mesh_name=mesh_name, chips=chips,
            cost=cost, hlo_text=hlo,
            peak_bytes_per_device=mem.get("peak_bytes_per_device", 0),
            model_flops=mf, cost_scale=scale,
            analytic_flops=analytic_step_flops(cfg, spec, kind),
        )
        result.update(
            status="ok",
            seconds=round(time.time() - t0, 1),
            memory=mem,
            roofline=json.loads(roof.to_json()),
            n_params=count_params(T.model_specs(cfg)),
            n_params_active=n_active,
        )
        if verbose:
            peak_gb = mem.get("peak_bytes_per_device", 0) / 2 ** 30
            print(f"[OK ] {arch:22s} {shape_name:12s} {mesh_name:8s} "
                  f"compile={result['seconds']:6.1f}s peak={peak_gb:6.2f}GiB/dev "
                  f"bottleneck={roof.bottleneck:10s} "
                  f"terms(ms) C={roof.compute_ms:.2f} M={roof.memory_ms:.2f} "
                  f"X={roof.collective_ms:.2f}")
    except Exception as e:
        result.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:],
                      seconds=round(time.time() - t0, 1))
        if verbose:
            print(f"[ERR] {arch:22s} {shape_name:12s} {mesh_name:8s} {e}")
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        suffix = ("_" + tag) if tag else ""
        fn = f"{arch}_{shape_name}_{mesh_name.replace('x','-')}{suffix}.json"
        with open(os.path.join(RESULTS_DIR, fn), "w") as f:
            json.dump(result, f, indent=2)
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) on the single-pod mesh")
    ap.add_argument("--gossip", default="ring",
                    choices=["ring", "star", "chain", "none"])
    ap.add_argument("--local-steps", type=int, default=1)
    args = ap.parse_args()

    failures = 0
    if args.all:
        for arch in ARCH_IDS:
            for shape in INPUT_SHAPES:
                r = dryrun_one(arch, shape, multi_pod=args.multi_pod,
                               gossip=args.gossip, local_steps=args.local_steps)
                if r["status"] == "error":
                    failures += 1
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        r = dryrun_one(args.arch, args.shape, multi_pod=args.multi_pod,
                       gossip=args.gossip, local_steps=args.local_steps)
        if r["status"] == "error":
            print(r.get("traceback", ""))
            failures = 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
