"""Production meshes.

Single pod: 256 chips as (16, 16) over ("data", "model").
Multi-pod:  2 pods x 256 chips as (2, 16, 16) over ("pod", "data", "model");
the "pod" axis carries the DPASGD silo replicas (DESIGN.md §3).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets ``xla_force_host_platform_device_count=512``
before any jax initialization.
"""

from __future__ import annotations

import contextlib

import jax


def compat_make_mesh(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` across jax versions: >= 0.6 wants explicit
    ``axis_types``; 0.4.x has no such parameter (nor AxisType)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def mesh_context(mesh: jax.sharding.Mesh):
    """``jax.set_mesh`` context when available (jax >= 0.6); null context
    on older jax, where every consumer takes the mesh explicitly."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return contextlib.nullcontext()


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_silo_mesh(n_silos: int, axis: str = "data") -> jax.sharding.Mesh:
    """1-D mesh hosting one silo per device index.

    Elastic membership sizes this to the *active* silo count, which may
    be (and after churn usually is) smaller than the device universe
    fixed at process start (``xla_force_host_platform_device_count`` on
    CPU, the physical slice on TPU): ``jax.make_mesh`` takes the first
    ``n_silos`` devices and the rest idle until silos rejoin."""
    n = len(jax.devices())
    if not (1 <= n_silos <= n):
        raise ValueError(f"need 1 <= n_silos <= {n} devices, got {n_silos}")
    return compat_make_mesh((n_silos,), (axis,))


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over the locally available devices (CPU tests/examples)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return compat_make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
CHIPS_PER_POD = 256
HBM_BYTES = 16 * 1024 ** 3    # 16 GiB per chip
