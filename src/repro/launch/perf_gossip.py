import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb — the paper's technique on TPU: DPASGD gossip schedule
comparison with 16 silos on one pod (mode A: silo axis = "data", each
silo a 16-chip TP group).

    PYTHONPATH=src python -m repro.launch.perf_gossip
"""

import json
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.fed import DPASGDConfig, make_train_step
from repro.fed.topology_runtime import plan_for_n_silos
from repro.launch import input_specs as IS
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.launch.hlo_analysis import collective_bytes, _COLLECTIVES
from repro.models import SILO_TP, transformer as T
from repro.models.act_sharding import activation_sharding
from repro.models.params import param_pspecs
from repro.optim import adamw

ARCH = "internlm2-1.8b"
N_SILOS = 16


def run_one(gossip_kind: str, gossip_impl: str = "ppermute"):
    t0 = time.time()
    mesh = make_production_mesh()
    cfg = get_config(ARCH, n_silos=N_SILOS, flash_vjp=True)
    accum = 1  # per-silo batch 16 = one microstep of 16 seqs (1/device-col)
    batch = IS.train_input_specs(cfg, "train_4k", accum_steps=accum)
    # mode A layout: [n_silos, s, B, S] with silos over "data"
    batch_ps = {k: P("data", *([None] * (len(v.shape) - 1)))
                for k, v in batch.items()}
    params_abs = IS.abstract_model_params(cfg, jnp.bfloat16)
    params_ps = param_pspecs(T.model_specs(cfg), SILO_TP, silo_leading=True)
    opt = adamw(1e-4)
    plan = plan_for_n_silos(gossip_kind, N_SILOS)
    fed = DPASGDConfig(local_steps=1, gossip_impl=gossip_impl,
                       silo_axis="data", accum_steps=accum)
    from repro.fed import make_train_step as mts

    step_fn = mts(cfg, fed, opt, plan, mesh)
    opt_abs = jax.eval_shape(jax.vmap(opt.init), params_abs)
    opt_ps = {"mu": params_ps, "nu": params_ps}
    state_abs = {"params": params_abs, "opt_state": opt_abs,
                 "step": jax.ShapeDtypeStruct((), jnp.int32)}
    state_ps = {"params": params_ps, "opt_state": opt_ps, "step": P()}
    with mesh_context(mesh), activation_sharding(None):
        compiled = jax.jit(
            step_fn,
            in_shardings=(IS.named(state_ps, mesh), IS.named(batch_ps, mesh)),
            out_shardings=(IS.named(state_ps, mesh), None),
        ).lower(state_abs, batch).compile()
    cb = collective_bytes(compiled.as_text())
    total = sum(v for k, v in cb.items() if k != "collective-count")
    ma = compiled.memory_analysis()
    peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes) / 2 ** 30
    print(f"{gossip_kind:>6s}/{gossip_impl:8s} transfers={plan.num_transfers:2d} "
          f"coll_total={total/2**30:7.3f} GiB/dev "
          f"cp={cb['collective-permute']/2**30:7.3f} "
          f"ag={cb['all-gather']/2**30:6.3f} ar={cb['all-reduce']/2**30:6.3f} "
          f"peak={peak:6.2f} GiB compile={time.time()-t0:.0f}s", flush=True)
    return {"kind": gossip_kind, "impl": gossip_impl, "coll": cb,
            "total": total, "peak_gib": peak}


def main():
    results = [run_one(k) for k in ("ring", "chain", "star")]
    results.append(run_one("ring", "einsum"))
    out = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "perf_gossip.json")
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    ring, chain, star = results[0], results[1], results[2]
    print(f"\nring vs star gossip traffic ratio: "
          f"{star['total'] / max(ring['total'], 1):.2f}x")


if __name__ == "__main__":
    sys.exit(main())
