"""Aggregate the dry-run JSONs into the §Dry-run and §Roofline markdown
tables for EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.launch.report [--mesh 16-16]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")

ARCH_ORDER = [
    "h2o-danube-1.8b", "xlstm-350m", "internvl2-76b", "internlm2-1.8b",
    "qwen3-moe-30b-a3b", "deepseek-v2-lite-16b", "granite-20b",
    "mistral-large-123b", "whisper-large-v3", "hymba-1.5b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str):
    rows = {}
    for path in glob.glob(os.path.join(RESULTS_DIR, f"*_{mesh}*.json")):
        try:
            d = json.load(open(path))
        except Exception:
            continue
        rows[(d["arch"], d["shape"])] = d
    return rows


def fmt_roofline_table(rows) -> str:
    out = [
        "| arch | shape | status | peak GiB/dev | compute ms | memory ms | "
        "collective ms | bottleneck | useful-flop | analytic ms |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = rows.get((arch, shape))
            if d is None:
                out.append(f"| {arch} | {shape} | MISSING | | | | | | | |")
                continue
            if d["status"] == "skipped":
                out.append(f"| {arch} | {shape} | skipped (sub-quadratic "
                           f"gate) | | | | | | | |")
                continue
            if d["status"] != "ok":
                out.append(f"| {arch} | {shape} | ERROR: "
                           f"{d.get('error','?')[:60]} | | | | | | | |")
                continue
            r = d["roofline"]
            peak = d["memory"].get("peak_bytes_per_device", 0) / 2 ** 30
            ana = r.get("analytic_compute_ms", 0.0)
            out.append(
                f"| {arch} | {shape} | ok | {peak:.2f} | "
                f"{r['compute_ms']:.2f} | {r['memory_ms']:.2f} | "
                f"{r['collective_ms']:.2f} | {r['bottleneck']} | "
                f"{r['useful_flop_ratio']:.2f} | {ana:.2f} |")
    return "\n".join(out)


def fmt_dryrun_table(rows) -> str:
    out = [
        "| arch | shape | compile s | GFLOP/dev | HBM GB/dev | coll GB/dev | "
        "collectives (AG/AR/RS/A2A/CP count) |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = rows.get((arch, shape))
            if not d or d["status"] != "ok":
                continue
            r = d["roofline"]
            cb = r["collective_breakdown"]
            chips = r["chips"]
            out.append(
                f"| {arch} | {shape} | {d.get('seconds','?')} | "
                f"{r['hlo_gflops']/chips:.1f} | {r['hlo_gbytes']/chips:.2f} | "
                f"{r['coll_gbytes']/chips:.3f} | "
                f"{cb['all-gather']//2**20}M/{cb['all-reduce']//2**20}M/"
                f"{cb['reduce-scatter']//2**20}M/{cb['all-to-all']//2**20}M/"
                f"{cb['collective-permute']//2**20}M x{cb['collective-count']} |")
    return "\n".join(out)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16-16")
    ap.add_argument("--kind", default="roofline", choices=["roofline", "dryrun"])
    args = ap.parse_args()
    rows = load(args.mesh)
    if args.kind == "roofline":
        print(fmt_roofline_table(rows))
    else:
        print(fmt_dryrun_table(rows))
    return 0


if __name__ == "__main__":
    sys.exit(main())
