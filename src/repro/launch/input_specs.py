"""ShapeDtypeStruct stand-ins + shardings for every (arch x input-shape)
combination — the dry-run lowers against these without allocating.

Sharding scheme (see DESIGN.md §6):

* params:  FSDP over "data" x TP over "model" (per-tensor logical rules);
           multi-pod training adds a leading silo dim sharded over "pod".
* batch:   [silos?, s, B_per, S] with B_per over "data" (+ "pod" serving).
* KV caches: batch over "data", *sequence* over "model" — keeps 32k/512k
           caches within HBM and is exactly how long-context serving
           shards caches in practice (ring-attention layout).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES
from repro.models import ModelConfig, FSDP_TP, FSDP_TP_PODS, param_pspecs
from repro.models import transformer as T
from repro.models.params import abstract_params, tree_map_specs

TOKEN_DT = jnp.int32


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def train_input_specs(
    cfg: ModelConfig, shape_name: str, *, local_steps: int = 1,
    accum_steps: int = 1,
) -> Dict[str, Any]:
    """Abstract DPASGD batch for a training shape.

    Layout: [n_silos?, s_local, accum?, B_micro, S]."""
    spec = INPUT_SHAPES[shape_name]
    S, B = spec["seq_len"], spec["global_batch"]
    n = cfg.n_silos
    per = B // max(n, 1)
    assert per % accum_steps == 0, (per, accum_steps)
    micro = per // accum_steps
    lead: Tuple[int, ...] = (local_steps,)
    if accum_steps > 1:
        lead = lead + (accum_steps,)
    if n > 1:
        lead = (n,) + lead
    S_tok = S - cfg.vision_prefix_len  # vision prefix counts toward seq budget
    out = {
        "tokens": sds(lead + (micro, S_tok), TOKEN_DT),
        "labels": sds(lead + (micro, S_tok), TOKEN_DT),
    }
    if cfg.is_encdec:
        out["enc_frames"] = sds(lead + (micro, cfg.encoder.seq_len, 128), jnp.bfloat16)
    if cfg.vision_prefix_len:
        out["vision_embeds"] = sds(lead + (micro, cfg.vision_prefix_len, 1024), jnp.bfloat16)
    return out


def train_batch_pspecs(cfg: ModelConfig, batch: Dict[str, Any], *,
                       multi_pod: bool, accum_steps: int = 1):
    n = cfg.n_silos
    out = {}
    n_lead = (1 if n > 1 else 0) + 1 + (1 if accum_steps > 1 else 0)
    for k, v in batch.items():
        ndim = len(v.shape)
        spec = [None] * ndim
        if n > 1:
            spec[0] = "pod"
        spec[n_lead] = "data"  # the microbatch dim
        out[k] = P(*spec)
    return out


def serve_input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """Abstract serving inputs (prefill or decode)."""
    spec = INPUT_SHAPES[shape_name]
    S, B = spec["seq_len"], spec["global_batch"]
    kind = spec["kind"]
    out: Dict[str, Any] = {}
    if kind == "prefill":
        S_tok = S - cfg.vision_prefix_len
        out["tokens"] = sds((B, S_tok), TOKEN_DT)
        if cfg.is_encdec:
            out["enc_frames"] = sds((B, cfg.encoder.seq_len, 128), jnp.bfloat16)
        if cfg.vision_prefix_len:
            out["vision_embeds"] = sds((B, cfg.vision_prefix_len, 1024), jnp.bfloat16)
    else:  # decode: one new token against a seq_len cache
        out["token"] = sds((B,), TOKEN_DT)
        out["position"] = sds((), TOKEN_DT)
        out["cache"] = abstract_cache(cfg, B, S)
    return out


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, batch, max_len, dtype)
    )
    if cfg.is_encdec:
        # add cross-attention caches
        H, hd = cfg.n_heads, cfg.head_dim
        Tenc = cfg.encoder.seq_len
        out = []
        for c in cache:
            c = dict(c)
            c["xk"] = sds((batch, Tenc, H, hd), dtype)
            c["xv"] = sds((batch, Tenc, H, hd), dtype)
            out.append(c)
        return out
    return cache


# ---------------------------------------------------------------------------
# sharding rules


def _divides(n: int, by: int) -> bool:
    return by > 0 and n % by == 0


def cache_pspec_leaf(shape: Tuple[int, ...], mesh_axis_sizes: Dict[str, int]):
    """Heuristic cache sharding: dim0 = batch over ('pod','data') or 'data'
    (when divisible), dim1 = sequence over 'model'; everything else local."""
    model = mesh_axis_sizes.get("model", 1)
    spec = [None] * len(shape)
    if len(shape) >= 1:
        spec[0] = _batch_lead_axes(shape, mesh_axis_sizes)
    if len(shape) >= 2 and _divides(shape[1], model) and shape[1] > model:
        spec[1] = "model"
    return P(*spec)


def cache_pspecs(cache_abstract, mesh: jax.sharding.Mesh):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def leaf(x):
        return cache_pspec_leaf(x.shape, sizes)

    return jax.tree_util.tree_map(leaf, cache_abstract)


def _batch_lead_axes(shape, sizes):
    """Shard the batch dim over ("pod","data") when divisible, else
    "data", else replicate."""
    if not shape or shape[0] <= 1:
        return None
    data = sizes.get("data", 1)
    pod = sizes.get("pod", 1)
    if pod > 1 and _divides(shape[0], pod * data):
        return ("pod", "data")
    if _divides(shape[0], data):
        return "data"
    return None


def serve_batch_pspecs(cfg: ModelConfig, batch: Dict[str, Any],
                       mesh: jax.sharding.Mesh):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out: Dict[str, Any] = {}
    for k, v in batch.items():
        if k == "cache":
            out[k] = cache_pspecs(v, mesh)
        elif k == "position":
            out[k] = P()
        else:
            shape = v.shape
            out[k] = P(*([_batch_lead_axes(shape, sizes)] + [None] * (len(shape) - 1)))
    return out


def model_param_pspecs(cfg: ModelConfig, *, multi_pod_training: bool = False):
    if cfg.n_silos > 1 and multi_pod_training:
        return param_pspecs(T.model_specs(cfg), FSDP_TP_PODS, silo_leading=True)
    if cfg.n_silos > 1:
        # silo dim over "data": fine-grained federation mode
        from repro.models import SILO_TP

        return param_pspecs(T.model_specs(cfg), SILO_TP, silo_leading=True)
    return param_pspecs(T.model_specs(cfg), FSDP_TP)


def abstract_model_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    specs = T.model_specs(cfg)
    base = abstract_params(specs, dtype)
    if cfg.n_silos > 1:
        base = jax.tree_util.tree_map(
            lambda x: sds((cfg.n_silos,) + tuple(x.shape), x.dtype), base
        )
    return base


def named(tree_pspec, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        tree_pspec,
        is_leaf=lambda x: isinstance(x, P),
    )
