"""Jittable step functions used by the launcher and the dry-run."""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.fed import DPASGDConfig, GossipPlan, make_train_step
from repro.models import ModelConfig
from repro.models import transformer as T
from repro.optim import Optimizer, adamw


def build_train_step(
    cfg: ModelConfig,
    *,
    optimizer: Optional[Optimizer] = None,
    gossip_impl: str = "ppermute",
    silo_axis: Optional[str] = "pod",
    plan: Optional[GossipPlan] = None,
    mesh: Optional[jax.sharding.Mesh] = None,
    local_steps: int = 1,
    accum_steps: int = 1,
    grad_pspecs=None,
) -> Callable:
    optimizer = optimizer or adamw(1e-4)
    fed = DPASGDConfig(local_steps=local_steps, gossip_impl=gossip_impl,
                       silo_axis=silo_axis, accum_steps=accum_steps)
    if cfg.n_silos > 1 and plan is None:
        from repro.fed.topology_runtime import plan_for_n_silos

        plan = plan_for_n_silos("ring", cfg.n_silos)
    return make_train_step(cfg, fed, optimizer, plan, mesh,
                           grad_pspecs=grad_pspecs)


def build_prefill_step(cfg: ModelConfig, max_len: int) -> Callable:
    def prefill_step(params, batch):
        return T.prefill(
            params, cfg, batch["tokens"], max_len,
            enc_frames=batch.get("enc_frames"),
            vision_embeds=batch.get("vision_embeds"),
        )

    return prefill_step


def build_decode_step(cfg: ModelConfig) -> Callable:
    def decode_fn(params, batch):
        return T.decode_step(params, cfg, batch["token"], batch["cache"],
                             batch["position"])

    return decode_fn
