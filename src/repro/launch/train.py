"""Training launcher: DPASGD over a designed topology.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --silos 4 --topology ring --steps 50

On this CPU container use ``--reduced`` (tiny same-family variant) and a
virtual device mesh (set automatically from --silos).  On TPU the same
entry point drives the production mesh.

``--dynamic`` attaches the online topology controller: the WAN between
the silos is simulated from a real underlay (``--underlay``) through a
seeded event scenario (``--scenario``), each training step advances the
simulated network clock by one communication round, and when the
controller detects throughput regression it re-designs the overlay and
hot-swaps the gossip plan — the train step is re-lowered on the new plan.
Membership is *elastic*: on ``SiloLeave``/``SiloJoin`` churn
(``--scenario random`` with ``--p-churn > 0``, or the deterministic
``--scenario churn``) the controller swaps a ``MembershipSlot`` and the
loop rebuilds the device mesh over the surviving silos and migrates the
silo-stacked state — survivors keep their parameters/optimizer slots
bit-identical, leavers' shards are dropped (``--churn-checkpoint`` saves
them first), joiners re-enter at the survivors' consensus average:

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --dynamic --underlay gaia --scenario linkfail --steps 60

``--designer matcha`` trains on a *randomized* schedule (MATCHA-style
budgeted matching activation): every step samples that round's gossip
plan from a shared round counter through a ``ScheduleSlot``, and the
consensus matrix enters the jitted step as a traced argument — per-round
topologies never recompile.  Works standalone (homogeneous MATCHA over
the complete silo graph) and under ``--dynamic``, where the initial
budget is swept on the measured underlay and the controller re-fits the
distribution on drift (``--scenario silodegrade`` stresses exactly that):

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --dynamic --designer matcha --scenario silodegrade
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--silos", type=int, default=4)
    ap.add_argument("--topology", default="ring",
                    choices=["ring", "star", "chain", "none", "mst",
                             "ring_2opt", "delta_mbst"])
    ap.add_argument("--gossip-impl", default="ppermute",
                    choices=["ppermute", "einsum", "pallas", "none"])
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch-per-silo", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--dynamic", action="store_true",
                    help="simulate a time-varying WAN and run the online "
                         "topology controller (silo count follows the underlay; "
                         "membership is elastic: on SiloJoin/SiloLeave the "
                         "mesh/state are rebuilt over the surviving silos)")
    ap.add_argument("--designer", default="auto",
                    choices=["auto", "sparse-rewire", "delta-rewire",
                             "hierarchical", "matcha"],
                    help="overlay designer: 'sparse-rewire' designs the "
                         "initial overlay with the rewire search behind "
                         "its size-dispatched engine (needs --dynamic) "
                         "and keeps it in the controller's re-design "
                         "pool; 'delta-rewire' forces the host "
                         "delta-priced climb; 'hierarchical' clusters "
                         "the silos and composes per-cluster searches "
                         "(both need --dynamic); 'matcha' trains on "
                         "a randomized schedule (per-round sampled gossip "
                         "plans; with --dynamic the budget is swept on "
                         "the measured underlay and re-fit on drift); "
                         "default: --topology heuristic")
    ap.add_argument("--matcha-budget", type=float, default=0.5,
                    help="static-mode MATCHA activation probability C_b "
                         "(with --dynamic the budget comes from the sweep)")
    ap.add_argument("--objective", default="tau",
                    choices=["tau", "time_to_eps"],
                    help="what design/re-design optimizes (needs --dynamic): "
                         "'tau' ranks candidates on cycle time alone; "
                         "'time_to_eps' also prices each candidate's "
                         "consensus contraction rho and ranks on the "
                         "composite tau / -log(rho) — wall clock per "
                         "e-fold of consensus-error decay (Sect. 4 "
                         "time-to-accuracy framing)")
    ap.add_argument("--underlay", default="gaia")
    ap.add_argument("--workload", default="inaturalist")
    ap.add_argument("--scenario", default="linkfail",
                    choices=["linkfail", "silodegrade", "random", "static",
                             "churn"])
    ap.add_argument("--scenario-seed", type=int, default=0)
    ap.add_argument("--p-churn", type=float, default=0.15,
                    help="--scenario random: probability mass of silo "
                         "leave/rejoin churn in the event mix (elastic "
                         "membership rebuilds the mesh/state on each)")
    ap.add_argument("--churn-checkpoint", default="",
                    help="directory: a departing silo's state row is "
                         "checkpointed there before its shard is dropped")
    ap.add_argument("--trace-out", default="",
                    help="write a JSONL flight-recorder trace here (turns "
                         "on spans + metrics; render/validate it with "
                         "scripts/obs_report.py)")
    ap.add_argument("--metrics-interval", type=int, default=10,
                    help="steps between 'round' trace records (0 disables "
                         "per-round records; decision records are always "
                         "written when --trace-out is set)")
    ap.add_argument("--verify-migration", action="store_true",
                    help="after each membership rebuild, re-gather the "
                         "migrated state and verify survivors are "
                         "bit-identical and joiners sit at the consensus "
                         "average (full-model host sweep: acceptance "
                         "tests/debugging, not production loops)")
    args = ap.parse_args()

    underlay = None
    silo_names = None
    if args.dynamic:
        # numpy-only imports: safe before the XLA device-count env is set
        from repro.core import make_underlay

        underlay = make_underlay(args.underlay)
        args.silos = underlay.num_silos
        # Site names for bottleneck attribution in the trace: the paper's
        # measured networks carry real city labels; synthetic ones don't.
        from repro.core.networks_data import AWS_NA_SITES, GAIA_SITES

        sites = {"gaia": GAIA_SITES, "aws_na": AWS_NA_SITES}.get(underlay.name)
        if sites is not None:
            silo_names = [name for name, _ in sites]

    if "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={max(args.silos, 1)}")

    import contextlib

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.data import SyntheticLMStream, FederatedBatcher
    from repro.fed import (
        DPASGDConfig, init_state, make_train_step, migrate_silo_state,
        slice_silo_row,
    )
    from repro.launch.mesh import make_silo_mesh, mesh_context
    from repro.fed.topology_runtime import plan_for_n_silos, plan_from_overlay
    from repro.obs import enable as obs_enable, span, summary as span_summary
    from repro.obs import metrics as obs_metrics
    from repro.obs.events import FlightRecorder, run_metadata
    from repro.obs.log import get_logger
    from repro.optim import momentum

    log = get_logger("train")
    recorder = None
    if args.trace_out:
        obs_enable()
        recorder = FlightRecorder(
            args.trace_out,
            meta=run_metadata({
                "underlay": args.underlay if args.dynamic else None,
                "scenario": args.scenario if args.dynamic else None,
                "designer": args.designer,
                "objective": args.objective,
                "steps": args.steps,
            }),
            silo_names=silo_names,
        )
        log.info("trace", path=args.trace_out)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    import dataclasses

    cfg = dataclasses.replace(cfg, n_silos=args.silos)
    n = args.silos
    mesh = make_silo_mesh(n)
    opt = momentum(args.lr, 0.9)
    # Randomized schedules sample a fresh topology per round, so their
    # consensus matrix must be a *traced* step input (einsum lowering) —
    # the baked ppermute/pallas schedules would recompile every round.
    sched_mode = args.designer == "matcha" and n > 1 and \
        args.gossip_impl != "none"
    if sched_mode and args.gossip_impl not in ("einsum",):
        log.warn("gossip-impl-override",
                 "matcha lowers gossip as a traced einsum",
                 requested=args.gossip_impl, used="einsum")
    fed = DPASGDConfig(local_steps=args.local_steps,
                       gossip_impl=("einsum" if sched_mode else
                                    args.gossip_impl) if n > 1 else "none",
                       silo_axis="data")

    timeline = controller = slot = sched_slot = mem_slot = None
    if args.dynamic:
        from repro.core import (
            DEFAULT_MATCHA_BUDGETS, OVERLAY_KINDS, TrainingParams, WORKLOADS,
            design_overlay, design_schedule,
        )
        from repro.dynamics import (
            ControllerConfig, DynamicTimeline, OnlineTopologyController,
            active_subgraph, churn_scenario, link_failure_scenario,
            random_scenario, silo_degrade_scenario, static_scenario,
        )
        from repro.fed.gossip import MembershipSlot, PlanSlot, ScheduleSlot

        M, Tc = WORKLOADS[args.workload]
        tp = TrainingParams(model_size_mbits=M, local_steps=args.local_steps)
        gc0 = underlay.connectivity_graph(comp_time_ms=Tc)
        if args.designer in ("sparse-rewire", "delta-rewire",
                             "hierarchical"):
            kind = args.designer.replace("-", "_")
        else:
            kind = args.topology if args.topology in OVERLAY_KINDS else "ring"
        overlay = design_overlay(kind, gc0, tp)
        schedule = None
        if args.designer == "matcha":
            schedule = design_schedule(
                "matcha", gc0, tp, sample_seed=args.scenario_seed,
                objective=args.objective)
            tau0 = schedule.price(gc0, tp, rounds=150, seeds=(0,)).tau_ms
            print(f"dynamic: {args.underlay} N={n}, matcha schedule "
                  f"(budget sweep -> C_b={schedule.budget:g}, "
                  f"{schedule.num_matchings} matchings), "
                  f"predicted tau={tau0:.1f} ms")
        else:
            tau0 = overlay.cycle_time_ms
            print(f"dynamic: {args.underlay} N={n}, {kind} overlay, "
                  f"predicted tau={tau0:.1f} ms")
        horizon = tau0 * max(args.steps, 1)
        if args.scenario == "linkfail":
            scenario = link_failure_scenario(
                underlay, Tc, t_fail_ms=horizon / 3,
                overlay_edges=overlay.edges, horizon_ms=horizon)
        elif args.scenario == "silodegrade":
            scenario = silo_degrade_scenario(
                underlay, Tc, silo=underlay.load_centrality_center(),
                t_ms=horizon / 3, horizon_ms=horizon)
        elif args.scenario == "random":
            # churn enabled: membership is elastic — on SiloJoin/SiloLeave
            # the controller swaps the MembershipSlot and the loop below
            # rebuilds the mesh and migrates the silo-stacked state
            scenario = random_scenario(
                underlay, Tc, seed=args.scenario_seed, horizon_ms=horizon,
                p_churn=args.p_churn)
        elif args.scenario == "churn":
            scenario = churn_scenario(
                underlay, Tc, silo=underlay.num_silos // 2,
                t_leave_ms=horizon / 4, t_rejoin_ms=horizon / 2,
                horizon_ms=horizon)
        else:
            scenario = static_scenario(underlay, Tc, horizon_ms=horizon)
        timeline = DynamicTimeline(scenario, tp)
        if recorder is not None:
            timeline.attach_recorder(recorder)
        provider = lambda: active_subgraph(  # noqa: E731 — shared by both modes
            timeline.current_epoch().gc, timeline.current_epoch().active)
        mem_slot = MembershipSlot(range(n), n)
        if schedule is not None:
            timeline.set_schedule(schedule)
            sched_slot = ScheduleSlot(schedule, n)
            cfg_ctl = ControllerConfig(
                seed=args.scenario_seed, schedule_family="matcha",
                matcha_budgets=DEFAULT_MATCHA_BUDGETS,
                objective=args.objective)
            slot_kw = dict(schedule_slot=sched_slot)
            plan = None
        else:
            timeline.set_overlay(overlay.edges)
            slot = PlanSlot(plan_from_overlay(overlay, n))
            cfg_ctl = ControllerConfig(
                seed=args.scenario_seed, objective=args.objective)
            slot_kw = dict(plan_slot=slot)
            plan = slot.plan
        controller = OnlineTopologyController(
            gc0, tp, overlay, schedule=schedule, config=cfg_ctl,
            connectivity_provider=provider,
            membership_slot=mem_slot,
            membership_provider=timeline.current_active,
            recorder=recorder,
            silo_names=silo_names,
            **slot_kw,
        )
    else:
        # Without --dynamic there are no network measurements to design
        # from; the measurement-based kinds fall back to their homogeneous
        # mesh equivalents.
        if args.designer in ("sparse-rewire", "delta-rewire",
                             "hierarchical"):
            log.warn("designer-ignored",
                     f"--designer {args.designer} needs --dynamic "
                     "(network measurements)")
        plan = None
        if args.designer == "matcha" and n > 1:
            # Homogeneous MATCHA: matchings of the complete silo graph.
            from repro.core import MatchaSchedule, greedy_edge_coloring
            from repro.fed.gossip import ScheduleSlot

            pairs = [(i, j) for i in range(n) for j in range(i + 1, n)]
            schedule = MatchaSchedule(
                matchings=tuple(
                    tuple(m) for m in greedy_edge_coloring(pairs)),
                budget=args.matcha_budget,
                sample_seed=args.scenario_seed,
            )
            sched_slot = ScheduleSlot(schedule, n)
            print(f"matcha: homogeneous K_{n} base graph, "
                  f"{schedule.num_matchings} matchings, "
                  f"C_b={schedule.budget:g} (per-round sampled plans)")
        else:
            kind = {"delta_mbst": "mst", "ring_2opt": "ring"}.get(
                args.topology, args.topology)
            if kind != args.topology:
                log.warn("topology-fallback",
                         "measurement-based kind needs --dynamic; using "
                         "homogeneous plan",
                         requested=args.topology, used=kind)
            plan = plan_for_n_silos(kind, n) if n > 1 else None

    def shard_state(state_host, mesh):
        def put(x):
            if getattr(x, "ndim", 0) > 0:
                return jax.device_put(x, NamedSharding(
                    mesh, P(*(("data",) + (None,) * (x.ndim - 1)))))
            return x

        return jax.tree_util.tree_map(put, state_host)

    # Recompile accounting: TraceCounter wraps the *pre-jit* step body, so
    # its count moves exactly when jax re-traces (initial lowering or a
    # hot-swap re-lower) — never on a cached executable call.
    from repro.analysis.recompile import TraceCounter

    def make_counted_step(*a, **kw):
        counted = TraceCounter(make_train_step(*a, **kw), name="train_step")
        trace_counters.append(counted)
        return counted

    trace_counters: list = []
    step_fn = make_counted_step(cfg, fed, opt, plan, mesh,
                                consensus_arg=sched_mode)
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    if n > 1:
        state = shard_state(state, mesh)
    # The data stream spans the full silo universe: under elastic
    # membership each silo label keeps its own (non-iid) distribution
    # across leaves/rejoins; the batcher stacks only the active labels.
    stream = SyntheticLMStream(cfg.vocab_size, args.seq_len, n_silos=max(n, 1))
    batcher = FederatedBatcher(stream, args.local_steps, args.batch_per_silo)
    jstep = jax.jit(step_fn)
    built_version = slot.version if slot is not None else 0
    built_mem_version = mem_slot.version if mem_slot is not None else 0
    active = tuple(range(n))
    t0 = time.time()
    with contextlib.ExitStack() as mesh_stack:
        mesh_stack.enter_context(mesh_context(mesh))
        for i in range(args.steps):
            if args.dynamic:
                # one train step == one communication round of simulated
                # WAN.  Simulated *first*, so the consensus mask below
                # (and, after the step, the controller) see the epoch the
                # round actually spans — a silo departing mid-round is
                # masked out of this very round's mix, not the next one's.
                duration = timeline.step()
            raw = batcher.batch(i, silos=active if args.dynamic else None)
            if recorder is not None:
                obs_metrics.counter("train.h2d_bytes").inc(
                    sum(getattr(v, "nbytes", 0) for v in raw.values()))
            b = {k: jnp.asarray(v) for k, v in raw.items()}
            if sched_mode:
                # per-round sampled consensus: traced argument, same
                # compiled step for every sampled topology
                A = jnp.asarray(sched_slot.matrix_for_round(i))
                if args.dynamic:
                    # renormalize over the silos still active at the end
                    # of this round: a leaver's stale params must not be
                    # mixed in during the one-round lag before the
                    # membership rebuild below
                    ep_active = set(timeline.current_active())
                    flags = [1.0 if v in ep_active else 0.0 for v in active]
                    mask = jnp.asarray(flags, jnp.float32)
                    n_act = int(sum(flags))  # host-side: no device sync
                    if n_act < len(active):
                        print(f"step {i:4d} consensus masked to "
                              f"{n_act}/{len(active)} silos "
                              f"(mid-round churn)", flush=True)
                    with span("train.step"):
                        state, metrics = jstep(state, b, A, mask)
                else:
                    with span("train.step"):
                        state, metrics = jstep(state, b, A)
            else:
                with span("train.step"):
                    state, metrics = jstep(state, b)
            if args.dynamic:
                redesign = controller.observe_round(duration)
                if redesign is not None:
                    timeline.set_schedule(redesign.schedule)
                    name = (redesign.overlay.name if redesign.overlay
                            else redesign.schedule.name)
                    rand = ("randomized schedule"
                            if redesign.schedule.is_randomized else "overlay")
                    print(f"step {i:4d} [t={timeline.now_ms/1e3:7.1f}s sim] "
                          f"controller re-design -> {rand} {name} "
                          f"tau {redesign.measured_ms:.1f} -> "
                          f"{redesign.predicted_tau_ms:.1f} ms "
                          f"({redesign.n_candidates} candidates in "
                          f"{redesign.elapsed_s*1e3:.0f} ms), bottleneck "
                          f"{redesign.bottleneck}", flush=True)
                if mem_slot is not None and mem_slot.version != built_mem_version:
                    # elastic membership: rebuild the mesh over the active
                    # silos and migrate the silo-stacked state (survivors
                    # keep their rows, leavers' shards are dropped,
                    # joiners enter at the survivors' consensus average)
                    new_active = mem_slot.active
                    # one host gather serves the migration, the leaver
                    # checkpoints, and the verification below
                    old_state = jax.device_get(state)
                    old_params = old_state["params"]
                    state_host, joined, left = migrate_silo_state(
                        old_state, active, new_active)
                    if args.churn_checkpoint and left:
                        from repro.checkpoint import save_silo_checkpoint

                        for v in left:
                            # full row: params AND optimizer slots (plus
                            # the shared step counter), so a later rejoin
                            # can recover exactly what the silo trained
                            row = slice_silo_row(old_state, active, v)
                            path = save_silo_checkpoint(
                                args.churn_checkpoint, v, row, step=i)
                            print(f"step {i:4d} leaver silo {v} "
                                  f"checkpoint -> {path}", flush=True)
                    n = len(new_active)
                    cfg = dataclasses.replace(cfg, n_silos=n)
                    mesh = make_silo_mesh(n)
                    mesh_stack.close()
                    mesh_stack.enter_context(mesh_context(mesh))
                    state = shard_state(state_host, mesh)
                    jstep = jax.jit(make_counted_step(
                        cfg, fed, opt,
                        None if sched_mode else slot.plan, mesh,
                        consensus_arg=sched_mode))
                    built_version = slot.version if slot is not None else 0
                    built_mem_version = mem_slot.version
                    msg = (f"step {i:4d} membership v{mem_slot.version}: "
                           f"{len(active)} -> {n} silos "
                           f"(left {list(left)}, joined {list(joined)}); "
                           f"mesh+state rebuilt")
                    if args.verify_migration:
                        # re-gather and check the migration invariants —
                        # a full-model host sweep, so opt-in (printed for
                        # the subprocess acceptance test to assert)
                        new_params = jax.device_get(state["params"])
                        oi = {v: k for k, v in enumerate(active)}
                        ni = {v: k for k, v in enumerate(new_active)}
                        survivors = [v for v in new_active if v in oi]
                        srows = [oi[v] for v in survivors]
                        # leaves are host already (device_get above):
                        # asarray is a view, not a transfer
                        olds = [np.asarray(o) for o in  # repro-lint: ignore[effect-purity]
                                jax.tree_util.tree_leaves(old_params)]
                        news = [np.asarray(w) for w in  # repro-lint: ignore[effect-purity]
                                jax.tree_util.tree_leaves(new_params)]
                        ok_surv = all(
                            np.array_equal(o[oi[v]], w[ni[v]])
                            for o, w in zip(olds, news) for v in survivors)
                        ok_join = all(
                            np.array_equal(
                                o[srows]
                                .mean(axis=0, dtype=np.float64)
                                .astype(o.dtype),
                                w[ni[v]])
                            for o, w in zip(olds, news) for v in joined)
                        msg += (f", survivors-bit-identical={ok_surv}, "
                                f"joiners-at-consensus={ok_join}")
                    print(msg, flush=True)
                    active = new_active
                if slot is not None and slot.version != built_version:
                    # hot-swap: re-lower the train step on the new plan
                    jstep = jax.jit(make_counted_step(cfg, fed, opt,
                                                      slot.plan, mesh))
                    built_version = slot.version
                # sched_slot swaps need no re-lowering: the consensus
                # matrix is a traced input, matrix_for_round follows the
                # new schedule automatically
            if (recorder is not None and args.metrics_interval
                    and i % args.metrics_interval == 0):
                recorder.emit(
                    "round",
                    step=i,
                    duration_ms=duration if args.dynamic else None,
                    predicted_window_ms=(
                        controller.expected_window_ms
                        if controller is not None else None),
                    measured_window_ms=(
                        controller.last_measured_ms
                        if controller is not None else None),
                    drift=(controller.last_drift
                           if controller is not None else None),
                )
                if args.dynamic:
                    obs_metrics.histogram("train.round_ms").observe(duration)
                obs_metrics.gauge("train.recompiles").set(
                    sum(c.count for c in trace_counters))
            if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
                # intentional sync: ~10 progress lines per run
                print(f"step {i:4d} loss {float(metrics['loss']):.4f} "  # repro-lint: ignore[effect-purity]
                      f"({time.time()-t0:.1f}s)", flush=True)
    if args.dynamic and controller is not None:
        final = controller.schedule
        desc = (f"randomized schedule {final.name} (C_b="
                f"{getattr(final, 'budget', 0):g})"
                if final.is_randomized
                else f"overlay {controller.overlay.name}")
        print(f"dynamic summary: {timeline.rounds_done} rounds in "
              f"{timeline.now_ms/1e3:.1f}s simulated, "
              f"{len(controller.redesigns)} re-design(s), "
              f"{mem_slot.version} membership swap(s) "
              f"({len(active)}/{underlay.num_silos} silos active), "
              f"final {desc} (tau {controller.predicted_tau_ms:.1f} ms)")
    if args.checkpoint:
        from repro.checkpoint import save_checkpoint

        save_checkpoint(args.checkpoint, jax.device_get(state["params"]),
                        step=args.steps)
        print(f"checkpoint -> {args.checkpoint}")
    if recorder is not None:
        obs_metrics.gauge("train.recompiles").set(
            sum(c.count for c in trace_counters))
        recorder.close(
            steps=args.steps,
            recompiles=sum(c.count for c in trace_counters),
            wall_s=time.time() - t0,
        )
        log.info("trace-written", path=args.trace_out,
                 spans=len(span_summary()))
    return 0


if __name__ == "__main__":
    sys.exit(main())
