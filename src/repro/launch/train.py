"""Training launcher: DPASGD over a designed topology.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --silos 4 --topology ring --steps 50

On this CPU container use ``--reduced`` (tiny same-family variant) and a
virtual device mesh (set automatically from --silos).  On TPU the same
entry point drives the production mesh.
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--silos", type=int, default=4)
    ap.add_argument("--topology", default="ring",
                    choices=["ring", "star", "chain", "none"])
    ap.add_argument("--gossip-impl", default="ppermute",
                    choices=["ppermute", "einsum", "pallas", "none"])
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch-per-silo", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--checkpoint", default="")
    args = ap.parse_args()

    if "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={max(args.silos, 1)}")

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.data import SyntheticLMStream, FederatedBatcher
    from repro.fed import DPASGDConfig, init_state, make_train_step
    from repro.launch.mesh import compat_make_mesh, mesh_context
    from repro.fed.topology_runtime import plan_for_n_silos
    from repro.optim import momentum

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    import dataclasses

    cfg = dataclasses.replace(cfg, n_silos=args.silos)
    n = args.silos
    mesh = compat_make_mesh((n,), ("data",))
    opt = momentum(args.lr, 0.9)
    plan = plan_for_n_silos(args.topology, n) if n > 1 else None
    fed = DPASGDConfig(local_steps=args.local_steps,
                       gossip_impl=args.gossip_impl if n > 1 else "none",
                       silo_axis="data")
    step_fn = make_train_step(cfg, fed, opt, plan, mesh)
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    if n > 1:
        def put(x):
            if getattr(x, "ndim", 0) > 0:
                return jax.device_put(x, NamedSharding(
                    mesh, P(*(("data",) + (None,) * (x.ndim - 1)))))
            return x

        state = jax.tree_util.tree_map(put, state)
    stream = SyntheticLMStream(cfg.vocab_size, args.seq_len, n_silos=max(n, 1))
    batcher = FederatedBatcher(stream, args.local_steps, args.batch_per_silo)
    jstep = jax.jit(step_fn)
    t0 = time.time()
    with mesh_context(mesh):
        for i in range(args.steps):
            b = {k: jnp.asarray(v) for k, v in batcher.batch(i).items()}
            state, metrics = jstep(state, b)
            if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                      f"({time.time()-t0:.1f}s)", flush=True)
    if args.checkpoint:
        from repro.checkpoint import save_checkpoint

        save_checkpoint(args.checkpoint, jax.device_get(state["params"]),
                        step=args.steps)
        print(f"checkpoint -> {args.checkpoint}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
