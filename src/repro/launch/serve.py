"""Serving launcher: batched prefill + decode with KV/state caches.

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-350m \
        --reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import init_params, transformer as T

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    max_len = args.max_len or (args.prompt_len + args.gen)
    key = jax.random.PRNGKey(0)
    params = init_params(key, T.model_specs(cfg))
    B = args.batch
    prompts = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab_size)
    extras = {}
    if cfg.is_encdec:
        extras["enc_frames"] = jnp.ones((B, cfg.encoder.seq_len, 128), jnp.float32)
    if cfg.vision_prefix_len:
        extras["vision_embeds"] = jnp.ones((B, cfg.vision_prefix_len, 1024),
                                           jnp.float32)

    t0 = time.time()
    prefill = jax.jit(lambda p, t: T.prefill(p, cfg, t, max_len,
                                             cache_dtype=jnp.float32, **extras))
    logits, cache = prefill(params, prompts)
    print(f"prefill[{B}x{args.prompt_len}] in {time.time()-t0:.2f}s")

    decode = jax.jit(lambda p, tok, c, pos: T.decode_step(p, cfg, tok, c, pos))
    tok = logits.argmax(-1).astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    pos0 = args.prompt_len + cfg.vision_prefix_len
    for i in range(args.gen - 1):
        logits, cache = decode(params, tok, cache, jnp.int32(pos0 + i))
        tok = logits.argmax(-1).astype(jnp.int32)
        out_tokens.append(tok)
    dt = time.time() - t0
    toks = B * (args.gen - 1)
    print(f"decode {args.gen - 1} steps x batch {B}: "
          f"{dt:.2f}s ({toks / max(dt, 1e-9):.1f} tok/s on CPU)")
    gen = jnp.stack(out_tokens, axis=1)
    print("generated ids[0]:", list(map(int, gen[0][:16])))
    assert bool(jnp.isfinite(logits).all())
    return 0


if __name__ == "__main__":
    sys.exit(main())
