"""Embed the generated dry-run/roofline tables into EXPERIMENTS.md
(replacing the <!-- DRYRUN_TABLE --> / <!-- ROOFLINE_TABLE --> markers).

    PYTHONPATH=src python -m repro.launch.finalize_experiments
"""

import os
import re
import sys

from .report import load, fmt_roofline_table, fmt_dryrun_table

ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..")


def main() -> int:
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    rows_single = load("16-16")
    rows_multi = load("2-16-16")

    dry = ("### Single-pod (16,16) — per-device dry-run artifacts\n\n"
           + fmt_dryrun_table(rows_single)
           + "\n\n### Multi-pod (2,16,16) — compile proof (512 devices)\n\n"
           + fmt_multi_status(rows_multi))
    roof = fmt_roofline_table(rows_single)
    text = re.sub(r"<!-- DRYRUN_TABLE -->", dry, text)
    text = re.sub(r"<!-- ROOFLINE_TABLE -->", roof, text)
    open(path, "w").write(text)
    print("EXPERIMENTS.md updated")
    return 0


def fmt_multi_status(rows) -> str:
    from .report import ARCH_ORDER, SHAPE_ORDER

    out = ["| arch | " + " | ".join(SHAPE_ORDER) + " |",
           "|---|" + "---|" * len(SHAPE_ORDER)]
    for arch in ARCH_ORDER:
        cells = []
        for shape in SHAPE_ORDER:
            d = rows.get((arch, shape))
            if d is None:
                cells.append("—")
            elif d["status"] == "ok":
                peak = d["memory"].get("peak_bytes_per_device", 0) / 2 ** 30
                cells.append(f"ok ({peak:.1f} GiB)")
            elif d["status"] == "skipped":
                cells.append("skip")
            else:
                cells.append("ERR")
        out.append(f"| {arch} | " + " | ".join(cells) + " |")
    return "\n".join(out)


if __name__ == "__main__":
    sys.exit(main())
