"""Sequential dry-run sweep driver: one subprocess per (arch x shape x
mesh) so each run gets a fresh XLA; skips combos whose result JSON
already exists (idempotent/resumable).

    PYTHONPATH=src python -m repro.launch.sweep [--multi-pod] [--force]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = [
    "internlm2-1.8b", "xlstm-350m", "hymba-1.5b", "h2o-danube-1.8b",
    "whisper-large-v3", "deepseek-v2-lite-16b", "qwen3-moe-30b-a3b",
    "granite-20b", "internvl2-76b", "mistral-large-123b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def result_path(arch: str, shape: str, multi_pod: bool) -> str:
    mesh = "2-16-16" if multi_pod else "16-16"
    return os.path.join(RESULTS_DIR, f"{arch}_{shape}_{mesh}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=2400)
    args = ap.parse_args()

    t0 = time.time()
    failures = []
    for arch in ARCHS:
        for shape in SHAPES:
            path = result_path(arch, shape, args.multi_pod)
            if os.path.exists(path) and not args.force:
                try:
                    st = json.load(open(path)).get("status")
                except Exception:
                    st = "corrupt"
                if st in ("ok", "skipped"):
                    print(f"[skip] {arch} {shape} (cached: {st})", flush=True)
                    continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape]
            if args.multi_pod:
                cmd.append("--multi-pod")
            print(f"[run ] {' '.join(cmd[3:])}  t={time.time()-t0:.0f}s", flush=True)
            try:
                r = subprocess.run(cmd, timeout=args.timeout,
                                   env={**os.environ, "PYTHONPATH": "src"})
                if r.returncode != 0:
                    failures.append((arch, shape))
            except subprocess.TimeoutExpired:
                print(f"[TIMEOUT] {arch} {shape}", flush=True)
                failures.append((arch, shape))
    print(f"sweep done in {time.time()-t0:.0f}s; failures: {failures}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
