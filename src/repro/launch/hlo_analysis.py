"""Roofline-term extraction from compiled dry-run artifacts.

* FLOPs / HBM bytes: ``compiled.cost_analysis()``.
* Collective bytes: parsed from the optimized HLO text — sum of the
  output-shape bytes of every all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute (the standard per-device wire-volume
  approximation).

Terms (per step, whole mesh; TPU v5e constants from launch.mesh):

    compute    = HLO_FLOPs / (chips * 197e12)
    memory     = HLO_bytes / (chips * 819e9)
    collective = collective_bytes / (chips * 50e9)
"""

from __future__ import annotations

import json
import math
import re
from dataclasses import dataclass, asdict
from typing import Dict, List, Optional, Tuple

from .mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of 'bf16[16,512]{1,0}' — also handles tuple shapes."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output bytes summed over the module."""
    out = {k: 0 for k in _COLLECTIVES}
    out["collective-count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # '%name = <shape> <op>(' — match op name after the shape
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        for kind in _COLLECTIVES:
            if op == kind or op.startswith(kind + "-"):
                out[kind] += _shape_bytes(shape_str)
                out["collective-count"] += 1
                break
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_gflops: float          # whole-mesh FLOPs per step / 1e9
    hlo_gbytes: float          # whole-mesh HBM bytes per step / 1e9
    coll_gbytes: float         # whole-mesh collective bytes / 1e9
    compute_ms: float
    memory_ms: float
    collective_ms: float
    bottleneck: str
    model_gflops: float        # 6*N*D (or 6*N_active*D) useful FLOPs
    useful_flop_ratio: float   # model / hlo
    analytic_gflops: float     # exact matmul accounting (whole mesh)
    analytic_compute_ms: float
    bytes_per_device: int      # peak from memory_analysis
    collective_breakdown: Dict[str, int]

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=2)


def make_roofline(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost: Dict[str, float],
    hlo_text: str,
    peak_bytes_per_device: int,
    model_flops: float,
    cost_scale: float = 1.0,
    analytic_flops: float = 0.0,
) -> Roofline:
    # cost_analysis flops/bytes are per-device for SPMD modules.
    # cost_scale corrects XLA's count-while-body-once accounting for the
    # outer (local_steps x grad-accum) scan; inner attention/mlstm scans
    # are fully unrolled at analysis time (cfg.analysis_unroll).
    flops = float(cost.get("flops", 0.0)) * cost_scale
    bytes_accessed = float(cost.get("bytes accessed", 0.0)) * cost_scale
    coll = collective_bytes(hlo_text)
    coll = {k: (int(v * cost_scale) if k != "collective-count" else v)
            for k, v in coll.items()}
    coll_total = sum(v for k, v in coll.items() if k != "collective-count")
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll_total / ICI_BW
    analytic_compute_s = (analytic_flops / chips) / PEAK_FLOPS_BF16
    # dominant term: compute judged on max(HLO, analytic) — non-unrolled
    # scan bodies make the HLO flop count a lower bound (see module doc)
    terms = {"compute": max(compute_s, analytic_compute_s),
             "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    total_flops = flops * chips
    return Roofline(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        hlo_gflops=total_flops / 1e9,
        hlo_gbytes=bytes_accessed * chips / 1e9,
        coll_gbytes=coll_total * chips / 1e9,
        compute_ms=compute_s * 1e3,
        memory_ms=memory_s * 1e3,
        collective_ms=collective_s * 1e3,
        bottleneck=bottleneck,
        model_gflops=model_flops / 1e9,
        useful_flop_ratio=(model_flops / total_flops) if total_flops else 0.0,
        analytic_gflops=analytic_flops / 1e9,
        analytic_compute_ms=analytic_compute_s * 1e3,
        bytes_per_device=peak_bytes_per_device,
        collective_breakdown=coll,
    )


def model_flops_estimate(cfg, shape_spec: Dict, n_params_active: float,
                         kind: str) -> float:
    """6*N*D for training, 2*N*D for inference forward (per step)."""
    if kind == "train":
        tokens = shape_spec["seq_len"] * shape_spec["global_batch"]
        return 6.0 * n_params_active * tokens
    if kind == "prefill":
        tokens = shape_spec["seq_len"] * shape_spec["global_batch"]
        return 2.0 * n_params_active * tokens
    # decode: one token per sequence
    return 2.0 * n_params_active * shape_spec["global_batch"]
