"""Analytic FLOP model — exact matmul accounting per (arch x shape).

Cross-checks the HLO-derived compute term: XLA's cost_analysis counts a
while-loop body once, so models with non-unrolled scans (mLSTM chunks,
sLSTM/mamba time steps) under-count in the HLO number; this model counts
every matmul from the known shapes.  Backward pass = 2x forward;
rematerialization adds ~1 extra forward for checkpointed blocks.
"""

from __future__ import annotations

from typing import Dict

from repro.models import ModelConfig

MLSTM_CHUNK = 128


def _attn_T_eff(S: int, T: int, causal: bool, window) -> float:
    """Average number of visible KV positions per query."""
    if window is not None:
        return min(window, (S + 1) / 2 if causal and T == S else T)
    if causal and T == S:
        return (S + 1) / 2
    return T


def _layer_flops(cfg: ModelConfig, kind: str, layer: int, S: int,
                 T: int, decode: bool) -> float:
    """Forward FLOPs for one layer over S query tokens with T KV context."""
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    F = cfg.d_ff
    f = 0.0
    if kind in ("attn", "attn_moe", "xattn"):
        window = cfg.sliding_window if cfg.layer_uses_window(layer) else None
        Te = _attn_T_eff(S, T, True, window)
        f += 2 * S * D * (H + 2 * K) * hd          # qkv proj
        f += 4 * S * Te * H * hd                    # qk^T + pv
        f += 2 * S * H * hd * D                     # out proj
        if kind == "xattn":
            Tenc = cfg.encoder.seq_len
            f += 2 * S * D * H * hd * 3 + 4 * S * Tenc * H * hd + 2 * S * H * hd * D
    elif kind in ("mla", "mla_moe"):
        m = cfg.mla
        R = m.kv_lora_rank
        qk = m.qk_nope_dim + m.qk_rope_dim
        Te = _attn_T_eff(S, T, True, None)
        f += 2 * S * D * R                          # down-proj
        f += 2 * S * R * H * (m.qk_nope_dim + m.v_head_dim)  # up-proj
        f += 2 * S * D * H * qk                     # wq
        f += 2 * S * Te * H * qk + 2 * S * Te * H * m.v_head_dim
        f += 2 * S * H * m.v_head_dim * D           # wo
    elif kind == "mlstm":
        e = cfg.ssm.expand if cfg.ssm else 2
        Di = e * D
        hdi = Di // H
        f += 2 * S * D * 2 * Di                     # up
        f += 3 * 2 * S * Di * Di                    # q,k,v
        if decode:
            f += 4 * S * H * hdi * hdi              # state update + readout
        else:
            C = min(MLSTM_CHUNK, S)
            f += H * (4 * S * C * hdi + 4 * S * hdi * hdi)
        f += 2 * S * Di * D                         # down
        return f
    elif kind == "slstm":
        dh = D // H
        f += 2 * S * D * 4 * D + 8 * S * D * dh
        f += 2 * S * D * D
        return f
    elif kind == "hymba":
        window = cfg.sliding_window if cfg.layer_uses_window(layer) else None
        Te = _attn_T_eff(S, T, True, window)
        f += 2 * S * D * (H + 2 * K) * hd + 4 * S * Te * H * hd + 2 * S * H * hd * D
        # mamba head
        Di = H * hd
        st = cfg.ssm.d_state if cfg.ssm else 16
        dtr = max(1, D // 16)
        f += 2 * S * D * 2 * Di + 2 * S * Di * 2 * st
        f += 2 * S * Di * dtr * 2 + 6 * S * Di * st + 2 * S * Di * D
    else:
        raise KeyError(kind)
    # FFN half
    if kind in ("attn_moe", "mla_moe"):
        m = cfg.moe
        f += 2 * S * D * m.n_experts                # router
        f += 6 * S * m.top_k * D * m.d_expert       # routed experts
        if m.n_shared:
            f += 6 * S * D * m.n_shared * (m.d_shared or m.d_expert)
    elif F:
        f += (4 if cfg.mlp_variant == "gelu" else 6) * S * D * F
    return f


def forward_flops(cfg: ModelConfig, S: int, T: int, *, decode: bool = False) -> float:
    """Per-sequence forward FLOPs (S new tokens, T total context)."""
    total = 0.0
    for layer, kind in enumerate(cfg.block_pattern):
        k = "xattn" if (cfg.is_encdec and kind == "attn") else kind
        total += _layer_flops(cfg, k, layer, S, T, decode)
    if cfg.is_encdec:
        Tenc = cfg.encoder.seq_len
        for layer in range(cfg.encoder.n_layers):
            total += _layer_flops(cfg, "attn", layer, Tenc, Tenc, False)
    total += 2 * S * cfg.d_model * cfg.padded_vocab_size  # lm head
    return total


def analytic_step_flops(cfg: ModelConfig, shape_spec: Dict, kind: str) -> float:
    """Whole-step FLOPs across the global batch (all silos)."""
    S, B = shape_spec["seq_len"], shape_spec["global_batch"]
    if kind == "train":
        S_tok = S - cfg.vision_prefix_len
        fwd = forward_flops(cfg, S, S)
        # bwd = 2x fwd; remat recompute ~= +1 fwd
        mult = 3.0 + (1.0 if cfg.remat else 0.0)
        return mult * fwd * B
    if kind == "prefill":
        return forward_flops(cfg, S, S) * B
    return forward_flops(cfg, 1, S, decode=True) * B
