"""Table 9 (Appendix H.4): Full-iNaturalist / ResNet-50 workload
(M = 161.06 Mbits, T_c = 946.7 ms), 1 Gbps core AND access links."""

from __future__ import annotations

from .common import cycle_times_for_network
import repro.core as C

PAPER = {  # STAR, MATCHA+, MST, dMBST, RING
    "gaia": (4444, 2721, 1498, 1363, 1156),
    "aws_na": (7785, 4384, 1441, 1297, 1119),
    "geant": (13585, 1894, 1944, 1464, 1196),
    "exodus": (26258, 1825, 2078, 1481, 1194),
    "ebone": (28753, 1933, 2448, 1481, 1178),
}


def run() -> None:
    print("# Table 9 — Full-iNaturalist (ResNet-50), 1 Gbps everywhere (ms)")
    print(f"{'network':8s} {'STAR':>15s} {'MATCHA+':>15s} {'MST':>15s} {'RING':>15s} {'star/ring':>10s}")
    for name in C.NETWORK_NAMES:
        ct = cycle_times_for_network(
            name, workload="full_inaturalist", core_gbps=1.0, access_gbps=1.0)
        p = PAPER[name]
        print(f"{name:8s} {ct['star']:7.0f} [{p[0]:5d}] {ct['matcha+']:7.0f} [{p[1]:5d}] "
              f"{ct['mst']:7.0f} [{p[2]:5d}] {ct['ring']:7.0f} [{p[4]:5d}]"
              f" {ct['star']/ct['ring']:10.2f}")
    print()


if __name__ == "__main__":
    run()
