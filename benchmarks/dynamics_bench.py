"""Dynamics subsystem throughput: online re-design and scenario simulation.

Two hot paths gate how far inside the training loop the controller can
live:

* **re-design latency** — one controller actuation on AWS North America
  (N=22): every designer heuristic plus a >=256-candidate batched ring
  search.  Acceptance: under 1 s wall clock (it is ~two orders under).
  Reported as candidates/sec.
* **simulator throughput** — batched piecewise recursion over a fleet of
  seeded random scenarios (B x [E, N, N] epoch stacks), reported as
  scenario-rounds/sec.

CSV: dynamics,<metric>,<value>,<derived>; ``run()`` returns the metrics
dict that ``benchmarks.run --json`` serializes (BENCH_dynamics.json).
"""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

import repro.core as C
from repro.dynamics import (
    design_best_overlay,
    random_scenario,
    simulate_scenarios_batched,
)

REDESIGN_CANDIDATES = 256
SIM_SCENARIOS = 64
SIM_ROUNDS = 200


def bench_redesign(n_candidates: int = REDESIGN_CANDIDATES) -> Dict[str, float]:
    M, Tc = C.WORKLOADS["inaturalist"]
    tp = C.TrainingParams(model_size_mbits=M, local_steps=1)
    u = C.make_underlay("aws_na")
    gc = u.connectivity_graph(comp_time_ms=Tc)
    rng = np.random.default_rng(0)
    # one warmup (numpy allocator, design caches nothing but page faults do)
    design_best_overlay(gc, tp, n_candidates=n_candidates, rng=rng)
    best = float("inf")
    scored = 0
    for _ in range(3):
        t0 = time.perf_counter()
        _, scored = design_best_overlay(gc, tp, n_candidates=n_candidates, rng=rng)
        best = min(best, time.perf_counter() - t0)
    return {
        "network": u.name,
        "num_silos": u.num_silos,
        "candidates": scored,
        "redesign_s": best,
        "candidates_per_sec": scored / best,
    }


def bench_simulator(
    n_scenarios: int = SIM_SCENARIOS, num_rounds: int = SIM_ROUNDS
) -> Dict[str, float]:
    M, Tc = C.WORKLOADS["inaturalist"]
    tp = C.TrainingParams(model_size_mbits=M, local_steps=1)
    u = C.make_underlay("gaia")
    gc = u.connectivity_graph(comp_time_ms=Tc)
    overlay = C.design_overlay("ring", gc, tp)
    horizon = num_rounds * overlay.cycle_time_ms
    scenarios = [
        random_scenario(u, Tc, seed=s, horizon_ms=horizon)
        for s in range(n_scenarios)
    ]
    t0 = time.perf_counter()
    times = simulate_scenarios_batched(scenarios, tp, overlay.edges, num_rounds)
    elapsed = time.perf_counter() - t0
    assert times.shape == (n_scenarios, num_rounds + 1, u.num_silos)
    total = n_scenarios * num_rounds
    return {
        "network": u.name,
        "scenarios": n_scenarios,
        "rounds": num_rounds,
        "simulate_s": elapsed,
        "scenario_rounds_per_sec": total / elapsed,
    }


def run() -> Dict[str, Dict[str, float]]:
    print("# dynamics: online re-design + event-driven simulator")
    rd = bench_redesign()
    print(f"dynamics,redesign_ms,{rd['redesign_s']*1e3:.1f},"
          f"N={rd['num_silos']} candidates={rd['candidates']}")
    print(f"dynamics,candidates_per_sec,{rd['candidates_per_sec']:.0f},")
    assert rd["redesign_s"] < 1.0, (
        f"re-design took {rd['redesign_s']:.2f}s (budget: 1s)")
    sim = bench_simulator()
    print(f"dynamics,simulate_ms,{sim['simulate_s']*1e3:.1f},"
          f"B={sim['scenarios']} R={sim['rounds']}")
    print(f"dynamics,scenario_rounds_per_sec,"
          f"{sim['scenario_rounds_per_sec']:.0f},")
    return {"redesign": rd, "simulator": sim}


if __name__ == "__main__":
    run()
