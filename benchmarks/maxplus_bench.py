"""Old-vs-new max-plus throughput: legacy dict Karp vs the batched engine.

Grid: N in {16, 64, 256} silos x B in {1, 128, 1024} candidate overlays.
For each cell we time

* ``legacy``  — per-overlay Python path: build a ``DelayDigraph`` from an
                edge dict, Tarjan SCC, nested-loop Karp (what every call
                to ``cycle_time`` did before the vectorized engine);
* ``np64``    — one ``batched_cycle_time`` call on the ``[B, N, N]`` stack
                (float64: bit-compatible with the legacy floats);
* ``np32``    — same call with ``dtype=np.float32`` (search-grade scoring);
* ``jax``     — the jitted ``batched_cycle_time_jax`` (f32, compile
                excluded);
* ``sp32``    — the edge-list engine (``batched_cycle_time_sparse``,
                f32) on the same graphs (ring + ~4N chords -> E ~ 6N).
                O(B*N*E) instead of O(B*N^3): loses to dense sweeps at
                small N, wins past N~256 — the full sparse-vs-dense
                scaling study lives in ``benchmarks/sparse_search_bench.py``.

Legacy timings at large (N, B) are measured on a subsample of the batch
and scaled linearly (marked ``~`` in the table) — the whole point is that
the legacy path is too slow to run 1024 x N=256 candidates.

CSV: maxplus,N,B,legacy_ms,np64_ms,np32_ms,jax_ms,sp32_ms,speedup_best
Acceptance target: >= 10x speedup at N=64, B=1024.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.maxplus import DelayDigraph, max_cycle_mean_legacy
from repro.core.maxplus_sparse import batched_cycle_time_sparse, dense_to_edge_batch
from repro.core.maxplus_vec import batched_cycle_time, batched_cycle_time_jax

# Cap on how many graphs the legacy path actually evaluates per cell.
_LEGACY_SAMPLE = {16: 128, 64: 32, 256: 4}


def random_strong_batch(rng: np.random.Generator, n: int, b: int):
    """B random strongly connected delay digraphs (ring + ~4N chords +
    self loops), as both edge dicts (legacy) and a [B, N, N] stack."""
    W = np.full((b, n, n), -np.inf)
    dicts: List[Dict[Tuple[int, int], float]] = []
    idx = np.arange(n)
    for k in range(b):
        d: Dict[Tuple[int, int], float] = {}
        ring_w = rng.uniform(0.5, 20.0, n)
        W[k, idx, (idx + 1) % n] = ring_w
        for i in range(n):
            d[(i, (i + 1) % n)] = float(ring_w[i])
        self_w = rng.uniform(0.0, 5.0, n)
        W[k, idx, idx] = self_w
        for i in range(n):
            d[(i, i)] = float(self_w[i])
        chords = rng.integers(0, n, size=(4 * n, 2))
        cw = rng.uniform(0.5, 20.0, 4 * n)
        for (i, j), w in zip(chords, cw):
            if i != j:
                W[k, int(i), int(j)] = float(w)
                d[(int(i), int(j))] = float(w)
        dicts.append(d)
    return dicts, W


def _time(fn, repeats: int = 1) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3  # ms


def run(assert_speedup: bool = True, smoke: bool = False) -> None:
    try:
        import jax

        jit_engine = jax.jit(batched_cycle_time_jax)
        have_jax = True
    except Exception:
        have_jax = False

    print("# max-plus engine throughput (ms per full candidate batch)")
    print("maxplus,N,B,legacy_ms,np64_ms,np32_ms,jax_ms,sp32_ms,speedup_best")
    checked = False
    grid_n = (16,) if smoke else (16, 64, 256)
    grid_b = (1, 128) if smoke else (1, 128, 1024)
    for n in grid_n:
        for b in grid_b:
            rng = np.random.default_rng(1000 * n + b)
            dicts, W = random_strong_batch(rng, n, b)

            sample = min(b, _LEGACY_SAMPLE[n])
            graphs = [
                DelayDigraph(tuple(range(n)), d) for d in dicts[:sample]
            ]
            legacy_sample_ms = _time(
                lambda: [max_cycle_mean_legacy(g) for g in graphs]
            )
            legacy_ms = legacy_sample_ms * (b / sample)
            approx = "~" if sample < b else ""

            np64_ms = _time(lambda: batched_cycle_time(W), repeats=2)
            W32 = W.astype(np.float32)
            np32_ms = _time(
                lambda: batched_cycle_time(W32, dtype=np.float32), repeats=2
            )

            if have_jax:
                jit_engine(W32).block_until_ready()  # compile
                jax_ms = _time(
                    lambda: jit_engine(W32).block_until_ready(), repeats=2
                )
                jax_str = f"{jax_ms:.2f}"
            else:
                jax_ms, jax_str = float("inf"), "n/a"

            eb32 = dense_to_edge_batch(W32)
            sp32_ms = _time(
                lambda: batched_cycle_time_sparse(eb32), repeats=2
            )

            best = legacy_ms / min(np64_ms, np32_ms, jax_ms, sp32_ms)
            print(
                f"maxplus,{n},{b},{approx}{legacy_ms:.2f},{np64_ms:.2f},"
                f"{np32_ms:.2f},{jax_str},{sp32_ms:.2f},{best:.1f}"
            )
            if n == 64 and b == 1024:
                checked = True
                print(
                    f"# acceptance N=64 B=1024: best speedup {best:.1f}x "
                    f"(target >= 10x)"
                )
                if assert_speedup:
                    assert best >= 10.0, (
                        f"vectorized engine only {best:.1f}x faster than "
                        "legacy at N=64, B=1024"
                    )
    assert checked or smoke  # the acceptance cell only exists on the full grid
    print()


if __name__ == "__main__":
    run()
