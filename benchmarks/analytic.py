"""Appendix B closed forms, validated against the max-plus machinery on a
synthetic homogeneous network (slow identical access links C, negligible
latency/computation):

    tau_RING  = M/C
    tau_STAR  = 2N * M/C
    tau_MATCHA+ >= C_b * max_degree(G_u) * M/C
"""

from __future__ import annotations

import numpy as np

import repro.core as C
from repro.core.delays import ConnectivityGraph, SiloParams, TrainingParams
from repro.core.delays import overlay_delay_matrix
from repro.core.maxplus_vec import batched_cycle_time


def homogeneous_gc(n: int, access_gbps: float) -> ConnectivityGraph:
    lat = {}
    bw = {}
    for i in range(n):
        for j in range(n):
            if i != j:
                lat[(i, j)] = 0.0
                bw[(i, j)] = 1e6  # core unconstrained
    params = {i: SiloParams(0.0, access_gbps, access_gbps) for i in range(n)}
    return ConnectivityGraph(tuple(range(n)), lat, bw, params)


def run() -> None:
    n = 16
    cap = 0.1  # Gbps — slow access links
    M = 42.88  # Mbits
    gc = homogeneous_gc(n, cap)
    tp = TrainingParams(model_size_mbits=M, local_steps=0)
    mc = M / cap  # ms

    ring = C.ring_overlay(gc, tp).cycle_time_ms
    star = C.star_overlay(gc, tp, center=0).cycle_time_ms
    print("# Appendix B closed forms (homogeneous slow access links)")
    print(f"ring: computed {ring:9.1f} ms   analytic M/C      = {mc:9.1f}")
    # star center serves n-1 leaves in both directions
    star_pred = 2 * (n - 1) * mc
    print(f"star: computed {star:9.1f} ms   analytic 2(N-1)M/C = {star_pred:9.1f}")
    assert abs(ring - mc) / mc < 0.05, "ring closed form violated"
    assert abs(star - star_pred) / star_pred < 0.05, "star closed form violated"
    ratio = star / ring
    print(f"star/ring = {ratio:.1f}  (paper: up to 2N = {2 * n})")

    # Batched engine sweep: one call scores every access-capacity scenario
    # (the ring closed form M/C must hold for each row of the batch).
    caps = [0.05, 0.1, 0.2, 0.5]
    ring_edges = [(i, (i + 1) % n) for i in range(n)]
    W = np.stack(
        [
            overlay_delay_matrix(homogeneous_gc(n, c), tp, ring_edges)
            for c in caps
        ]
    )
    taus = batched_cycle_time(W)
    print("# batched ring sweep: cap_gbps tau_ms analytic_M/C")
    for c, tau in zip(caps, taus):
        print(f"batched_ring,{c},{tau:.1f},{M / c:.1f}")
        assert abs(tau - M / c) / (M / c) < 0.05, "batched closed form violated"
    print()


if __name__ == "__main__":
    run()
