"""Table 3: iNaturalist cycle times for 6 overlays on the 5 networks.

1 Gbps core, 10 Gbps access, s = 1.  Prints our values next to the
paper's and the RING-vs-STAR / RING-vs-MATCHA+ speedups."""

from __future__ import annotations

import time

from .common import PAPER_TABLE3, cycle_times_for_network
import repro.core as C


def run(smoke: bool = False) -> None:
    print("# Table 3 — cycle time (ms); paper values in []")
    hdr = f"{'network':8s} {'STAR':>14s} {'MATCHA+':>14s} {'MST':>14s} {'dMBST':>14s} {'RING':>14s}  {'ring/star':>9s} {'ring/matcha':>11s}"
    print(hdr)
    networks = C.NETWORK_NAMES[:2] if smoke else C.NETWORK_NAMES
    for name in networks:
        t0 = time.time()
        ct = cycle_times_for_network(name)
        p = PAPER_TABLE3[name]
        cols = []
        for i, k in enumerate(("star", "matcha+", "mst", "delta_mbst", "ring")):
            cols.append(f"{ct[k]:6.0f} [{p[i]:4d}]")
        su_star = ct["star"] / ct["ring"]
        su_mat = ct["matcha+"] / ct["ring"]
        print(f"{name:8s} " + " ".join(cols) +
              f"  {su_star:9.2f} {su_mat:11.2f}   ({time.time()-t0:.1f}s)")
    print()
    print("table3,checks: ring faster than star on all 5 networks")


if __name__ == "__main__":
    run()
