"""Sparse engine + topology search engines: the large-N scaling story.

Four questions gate the ROADMAP's past-the-dense-wall direction:

* **scoring** — batched cycle-time evaluation of *sparse* overlays
  (degree <= 8 circulant-style digraphs: ring + 6 random chord offsets
  + self loops, E ~ 8N) at N in {64, 256, 1024}.  The dense engine pays
  O(B*N^3) regardless of sparsity; the edge-list engine pays O(B*N*E).
  Dense timings at N=1024 are measured on a batch subsample and scaled
  linearly (marked ``~``).  The jitted path is timed per segment-max
  implementation (``xla`` scatter vs the degree-``padded`` gather
  layout), and the size dispatcher's pick is recorded.  Acceptance:
  some sparse path beats the dense engine at N=1024, and the dispatched
  jax path no longer loses to host numpy there.
* **delta pricing** — :func:`repro.core.topologies.search_overlays_delta`
  with incremental certificate pricing vs the identical climb forced
  through the full-Karp oracle (``pricing="full"``), measured in
  proposals/second at N=1024, degree <= 8.  Acceptance: >= 5x.
* **hierarchical** — :func:`search_overlays_hierarchical` on a
  synthetic clustered 4096-silo WAN: the N~10^4-scale design loop must
  complete and return a strongly-connected overlay.
* **search** — :func:`repro.core.topologies.search_overlays_jit` (the
  device-side rewire hill climb) against the controller's 256-candidate
  random-ring search on the Gaia underlay at *equal wall-clock budget*:
  the ring search is re-run with however many candidates fit in the
  rewire search's (warm, compile-excluded) wall time.  Acceptance: the
  rewire search's overlay cycle time is <= the ring search's.

CSV rows: ``sparse_search,score,...``, ``sparse_search,delta,...``,
``sparse_search,hier,...``, and ``sparse_search,gaia,<metric>,<value>``.
``run()`` returns the metrics dict that ``benchmarks.run --json``
serializes (BENCH_sparse_search.json); ``run(smoke=True)`` is the CI
configuration (tiny sizes, perf asserts off, correctness asserts on).
"""

from __future__ import annotations

import math
import time
from typing import Dict, List, Tuple

import numpy as np

import repro.core as C
from repro.core.delays import ConnectivityGraph, SiloParams
from repro.core.maxplus_sparse import (
    EdgeBatch,
    batched_cycle_time_sparse,
    batched_cycle_time_sparse_jax,
    cycle_time_engine,
    edge_batch_to_dense,
)
from repro.core.maxplus_vec import batched_cycle_time
from repro.core.topologies import (
    Overlay,
    search_overlays_delta,
    search_overlays_hierarchical,
    search_overlays_jit,
)
from repro.dynamics import search_ring_candidates

# (batch scored by the sparse paths, batch actually timed on the dense path)
_SCORING_GRID = {64: (256, 256), 256: (32, 8), 1024: (8, 2)}
_SCORING_GRID_SMOKE = {64: (16, 16), 256: (4, 2)}
_CHORDS = 6  # extra out-edges per vertex -> degree <= 8 with the ring arc


def random_sparse_overlays(rng: np.random.Generator, n: int, b: int) -> EdgeBatch:
    """B strongly-connected degree-<=8 delay digraphs as an edge batch.

    Ring over a random permutation + ``_CHORDS`` random circulant chord
    offsets per graph (out-degree = in-degree = 1 + ``_CHORDS``) + self
    loops — the sparse-overlay family the search explores.
    """
    E = n * (2 + _CHORDS)
    src = np.empty((b, E), dtype=np.int32)
    dst = np.empty((b, E), dtype=np.int32)
    w = np.empty((b, E), dtype=np.float64)
    idx = np.arange(n, dtype=np.int32)
    for k in range(b):
        perm = rng.permutation(n).astype(np.int32)
        cols = [(perm, np.roll(perm, -1))]  # ring
        offsets = rng.choice(np.arange(2, n - 1), size=_CHORDS, replace=False)
        for off in offsets:
            cols.append((idx, (idx + off) % n))
        cols.append((idx, idx))  # self loops
        src[k] = np.concatenate([s for (s, _) in cols])
        dst[k] = np.concatenate([d for (_, d) in cols])
        w[k] = rng.uniform(0.5, 20.0, E)
        w[k, -n:] = rng.uniform(0.0, 5.0, n)  # computation self-delays
    return EdgeBatch(src, dst, w, n)


def _time(fn, repeats: int = 2) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3  # ms


def bench_scoring(smoke: bool = False) -> Dict[str, Dict[str, float]]:
    try:
        import jax

        jit_sparse = jax.jit(
            batched_cycle_time_sparse_jax, static_argnums=3,
            static_argnames=("kernel", "max_in_degree"))
        have_jax = True
    except Exception:
        have_jax = False

    deg = 2 + _CHORDS  # in-degree bound incl. the self-loop
    print("# batched cycle-time scoring of sparse (degree<=8) overlays")
    print("sparse_search,score,N,B,E,dense_ms,sp64_ms,sp32_ms,"
          "spjax_xla_ms,spjax_padded_ms,engine")
    out: Dict[str, Dict[str, float]] = {}
    grid = _SCORING_GRID_SMOKE if smoke else _SCORING_GRID
    for n, (b, b_dense) in grid.items():
        rng = np.random.default_rng(n)
        eb = random_sparse_overlays(rng, n, b)
        W = edge_batch_to_dense(eb).astype(np.float32)

        dense_sub_ms = _time(
            lambda: batched_cycle_time(W[:b_dense], dtype=np.float32),
            repeats=2 if n < 1024 else 1,
        )
        dense_ms = dense_sub_ms * (b / b_dense)
        approx = "~" if b_dense < b else ""

        sp64_ms = _time(lambda: batched_cycle_time_sparse(eb))
        eb32 = EdgeBatch(eb.src, eb.dst, eb.w.astype(np.float32), n)
        sp32_ms = _time(lambda: batched_cycle_time_sparse(eb32))
        if have_jax:
            w32 = eb32.w

            def _jit(kernel, **kw):
                def call():
                    return jit_sparse(
                        eb.src, eb.dst, w32, n, kernel=kernel, **kw
                    ).block_until_ready()

                call()  # compile
                return _time(call)

            spjax_ms = _jit("xla")
            padded_ms = _jit("padded", max_in_degree=deg)
            jax_str = f"{spjax_ms:.2f},{padded_ms:.2f}"
        else:
            spjax_ms = padded_ms = float("inf")
            jax_str = "n/a,n/a"
        engine = cycle_time_engine(n, eb.max_edges, b)

        # correctness spot check: sparse f64 == dense f64 on a subsample
        ref = batched_cycle_time(edge_batch_to_dense(eb)[:2])
        got = batched_cycle_time_sparse(
            EdgeBatch(eb.src[:2], eb.dst[:2], eb.w[:2], n)
        )
        np.testing.assert_allclose(got, ref, rtol=1e-9)

        print(
            f"sparse_search,score,{n},{b},{eb.max_edges},{approx}{dense_ms:.2f},"
            f"{sp64_ms:.2f},{sp32_ms:.2f},{jax_str},{engine}"
        )
        best_sparse = min(sp64_ms, sp32_ms, spjax_ms, padded_ms)
        out[f"N{n}"] = {
            "batch": b,
            "edges": eb.max_edges,
            "dense_f32_ms": dense_ms,
            "sparse_f64_ms": sp64_ms,
            "sparse_f32_ms": sp32_ms,
            "sparse_jax_xla_ms": spjax_ms if math.isfinite(spjax_ms) else None,
            "sparse_jax_padded_ms": (
                padded_ms if math.isfinite(padded_ms) else None),
            "engine": engine,
            "speedup_vs_dense": dense_ms / best_sparse,
        }
        if n == 1024 and not smoke:
            print(
                f"# acceptance N=1024: sparse {best_sparse:.1f} ms vs dense "
                f"{dense_ms:.1f} ms ({dense_ms / best_sparse:.1f}x)"
            )
            assert best_sparse < dense_ms, (
                f"sparse path ({best_sparse:.1f} ms) does not beat dense "
                f"({dense_ms:.1f} ms) at N=1024"
            )
            # the dispatched jax path (padded on CPU) must not lose to
            # the host-numpy scorer any more
            host_best = min(sp64_ms, sp32_ms)
            assert padded_ms < host_best, (
                f"padded jax path ({padded_ms:.1f} ms) still loses to host "
                f"numpy ({host_best:.1f} ms) at N=1024"
            )
    return out


def synthetic_clustered_gc(
    n: int, n_clusters: int, seed: int = 0, comp_ms: float = 5.0
) -> Tuple[ConnectivityGraph, List[int]]:
    """Sparse clustered WAN at O(N) connectivity-dict size: contiguous
    silo-id clusters with a low-latency intra ring + two chords, and
    high-latency bidirectional border pairs joining consecutive clusters
    (always including ``(last of c, first of c+1)``, so the identity
    ring is fully routed and can seed searches).  Returns ``(gc,
    cluster labels aligned with gc.silos)`` — the hierarchical
    designer's ``labels`` input."""
    rng = np.random.default_rng(seed)
    bounds = np.linspace(0, n, n_clusters + 1).astype(int)
    members = [list(range(bounds[c], bounds[c + 1]))
               for c in range(n_clusters)]
    members = [m for m in members if m]
    lat: Dict[Tuple[int, int], float] = {}
    bw: Dict[Tuple[int, int], float] = {}

    def link(a: int, b: int, l: float) -> None:
        lat[(a, b)] = lat[(b, a)] = l
        bw[(a, b)] = bw[(b, a)] = float(rng.uniform(0.5, 2.0))

    labels = [0] * n
    for c, mem in enumerate(members):
        m = len(mem)
        for k, a in enumerate(mem):
            labels[a] = c
            link(a, mem[(k + 1) % m], float(rng.uniform(1.0, 5.0)))
            for off in (2, 3):
                if m > off + 1:
                    link(a, mem[(k + off) % m], float(rng.uniform(1.0, 5.0)))
        nxt = members[(c + 1) % len(members)]
        link(mem[-1], nxt[0], float(rng.uniform(20.0, 60.0)))
        link(int(mem[rng.integers(m)]), int(nxt[rng.integers(len(nxt))]),
             float(rng.uniform(20.0, 60.0)))
    params = {
        i: SiloParams(comp_ms, float(rng.uniform(5.0, 10.0)),
                      float(rng.uniform(5.0, 10.0)))
        for i in range(n)
    }
    return ConnectivityGraph(tuple(range(n)), lat, bw, params), labels


def _identity_ring(n: int) -> Overlay:
    return Overlay(
        name="ring", cycle_time_ms=float("inf"),
        edges=tuple((i, (i + 1) % n) for i in range(n)))


def bench_delta_pricing(smoke: bool = False) -> Dict[str, float]:
    """Delta-certificate pricing vs the full-Karp oracle inside the same
    climb: proposals/second at N=1024 (the >= 5x acceptance gate)."""
    n = 128 if smoke else 1024
    gc, _ = synthetic_clustered_gc(n, max(2, n // 64), seed=1)
    M, _ = C.WORKLOADS["inaturalist"]
    tp = C.TrainingParams(model_size_mbits=M, local_steps=1)
    ring = _identity_ring(n)

    def climb(pricing: str, n_steps: int) -> Tuple[float, float, Dict]:
        stats: Dict[str, int] = {}
        t0 = time.perf_counter()
        ov = search_overlays_delta(
            gc, tp, n_restarts=1, n_steps=n_steps, delta_max=8, seed=0,
            incumbent=ring, pricing=pricing, stats_out=stats)
        dt = time.perf_counter() - t0
        return stats["proposals"] / dt, ov.cycle_time_ms, stats

    delta_rate, delta_tau, stats = climb("delta", 200 if smoke else 2000)
    full_rate, full_tau, _ = climb("full", 100 if smoke else 60)

    print("# delta-evaluated rewire pricing vs full-Karp oracle")
    print(f"sparse_search,delta,N,{n},proposals_per_s,{delta_rate:.1f},"
          f"full_per_s,{full_rate:.1f},speedup,{delta_rate / full_rate:.1f}")
    print(f"sparse_search,delta,fast,{stats['fast']},propagated,"
          f"{stats['propagated']},reanchor,{stats['reanchor']},"
          f"accepts,{stats['accepts']}")
    assert np.isfinite(delta_tau) and np.isfinite(full_tau)
    if not smoke:
        assert delta_rate >= 5.0 * full_rate, (
            f"delta pricing {delta_rate:.1f} proposals/s is not >= 5x the "
            f"full-Karp climb {full_rate:.1f} at N={n}")
    return {
        "num_silos": n,
        "delta_proposals_per_s": delta_rate,
        "full_proposals_per_s": full_rate,
        "speedup": delta_rate / full_rate,
        "delta_tau_ms": delta_tau,
        "full_tau_ms": full_tau,
        "fast": stats["fast"],
        "propagated": stats["propagated"],
        "reanchor": stats["reanchor"],
    }


def bench_hierarchical(smoke: bool = False) -> Dict[str, float]:
    """N~10^4-scale design: the hierarchical search must complete on a
    4096-silo clustered WAN and return a strongly-connected overlay."""
    n = 256 if smoke else 4096
    n_clusters = max(2, n // 64)
    gc, labels = synthetic_clustered_gc(n, n_clusters, seed=2)
    M, _ = C.WORKLOADS["inaturalist"]
    tp = C.TrainingParams(model_size_mbits=M, local_steps=1)
    t0 = time.perf_counter()
    ov = search_overlays_hierarchical(
        gc, tp, labels=labels, n_restarts=1, n_steps=16 if smoke else 24,
        delta_max=8, seed=0, incumbent=_identity_ring(n))
    dt = time.perf_counter() - t0
    print("# hierarchical decomposition at scale")
    print(f"sparse_search,hier,N,{n},clusters,{n_clusters},"
          f"tau_ms,{ov.cycle_time_ms:.2f},wall_s,{dt:.1f},"
          f"edges,{len(ov.edges)}")
    assert np.isfinite(ov.cycle_time_ms) and ov.cycle_time_ms > 0
    return {
        "num_silos": n,
        "n_clusters": n_clusters,
        "tau_ms": ov.cycle_time_ms,
        "wall_s": dt,
        "edges": len(ov.edges),
    }


def bench_gaia_search(
    n_restarts: int = 16, n_steps: int = 96
) -> Dict[str, float]:
    M, Tc = C.WORKLOADS["inaturalist"]
    tp = C.TrainingParams(model_size_mbits=M, local_steps=1)
    u = C.make_underlay("gaia")
    gc = u.connectivity_graph(comp_time_ms=Tc)

    # warm up (jit compile + numpy allocator), then time the real run
    search_overlays_jit(gc, tp, n_restarts=n_restarts, n_steps=n_steps, seed=0)
    t0 = time.perf_counter()
    ov = search_overlays_jit(
        gc, tp, n_restarts=n_restarts, n_steps=n_steps, seed=1
    )
    search_s = time.perf_counter() - t0

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    ring256 = search_ring_candidates(gc, tp, 256, rng)
    ring256_s = time.perf_counter() - t0
    # equal wall-clock budget: as many ring candidates as fit in search_s
    n_equal = max(256, int(256 * search_s / max(ring256_s, 1e-9)))
    t0 = time.perf_counter()
    ring_eq = search_ring_candidates(gc, tp, n_equal, np.random.default_rng(0))
    ring_eq_s = time.perf_counter() - t0

    print("# gaia: jitted rewire search vs random-ring search (equal budget)")
    print(f"sparse_search,gaia,rewire_ms,{search_s*1e3:.1f},"
          f"restarts={n_restarts} steps={n_steps}")
    print(f"sparse_search,gaia,rewire_tau_ms,{ov.cycle_time_ms:.2f},")
    print(f"sparse_search,gaia,ring256_tau_ms,{ring256.cycle_time_ms:.2f},"
          f"{ring256_s*1e3:.1f}ms")
    print(f"sparse_search,gaia,ring_equal_tau_ms,{ring_eq.cycle_time_ms:.2f},"
          f"candidates={n_equal} in {ring_eq_s*1e3:.1f}ms")
    assert ov.cycle_time_ms <= ring256.cycle_time_ms + 1e-9, (
        f"rewire search tau {ov.cycle_time_ms:.2f} worse than 256-ring "
        f"search {ring256.cycle_time_ms:.2f}"
    )
    assert ov.cycle_time_ms <= ring_eq.cycle_time_ms + 1e-9, (
        f"rewire search tau {ov.cycle_time_ms:.2f} worse than equal-budget "
        f"ring search {ring_eq.cycle_time_ms:.2f} ({n_equal} candidates)"
    )
    return {
        "network": u.name,
        "num_silos": u.num_silos,
        "rewire_s": search_s,
        "rewire_tau_ms": ov.cycle_time_ms,
        "ring256_s": ring256_s,
        "ring256_tau_ms": ring256.cycle_time_ms,
        "ring_equal_candidates": n_equal,
        "ring_equal_tau_ms": ring_eq.cycle_time_ms,
    }


def run(smoke: bool = False) -> Dict[str, Dict]:
    scoring = bench_scoring(smoke=smoke)
    print()
    delta = bench_delta_pricing(smoke=smoke)
    print()
    hier = bench_hierarchical(smoke=smoke)
    print()
    gaia = bench_gaia_search(
        n_restarts=4 if smoke else 16, n_steps=32 if smoke else 96)
    print()
    return {
        "scoring": scoring,
        "delta_pricing": delta,
        "hierarchical": hier,
        "gaia_search": gaia,
    }


if __name__ == "__main__":
    run()
