"""Sparse engine + jitted topology search: the large-N scaling story.

Two questions gate the ROADMAP's past-the-dense-wall direction:

* **scoring** — batched cycle-time evaluation of *sparse* overlays
  (degree <= 8 circulant-style digraphs: ring + 6 random chord offsets
  + self loops, E ~ 8N) at N in {64, 256, 1024}.  The dense engine pays
  O(B*N^3) regardless of sparsity; the edge-list engine pays O(B*N*E).
  Dense timings at N=1024 are measured on a batch subsample and scaled
  linearly (marked ``~``).  Acceptance: some sparse path beats the dense
  engine at N=1024.
* **search** — :func:`repro.core.topologies.search_overlays_jit` (the
  device-side rewire hill climb) against the controller's 256-candidate
  random-ring search on the Gaia underlay at *equal wall-clock budget*:
  the ring search is re-run with however many candidates fit in the
  rewire search's (warm, compile-excluded) wall time.  Acceptance: the
  rewire search's overlay cycle time is <= the ring search's.

CSV rows: ``sparse_search,score,N,B,E,dense_ms,sp64_ms,sp32_ms,spjax_ms``
and ``sparse_search,gaia,<metric>,<value>``.  ``run()`` returns the
metrics dict that ``benchmarks.run --json`` serializes
(BENCH_sparse_search.json).
"""

from __future__ import annotations

import math
import time
from typing import Dict

import numpy as np

import repro.core as C
from repro.core.maxplus_sparse import (
    EdgeBatch,
    batched_cycle_time_sparse,
    batched_cycle_time_sparse_jax,
    edge_batch_to_dense,
)
from repro.core.maxplus_vec import batched_cycle_time
from repro.core.topologies import search_overlays_jit
from repro.dynamics import search_ring_candidates

# (batch scored by the sparse paths, batch actually timed on the dense path)
_SCORING_GRID = {64: (256, 256), 256: (32, 8), 1024: (8, 2)}
_CHORDS = 6  # extra out-edges per vertex -> degree <= 8 with the ring arc


def random_sparse_overlays(rng: np.random.Generator, n: int, b: int) -> EdgeBatch:
    """B strongly-connected degree-<=8 delay digraphs as an edge batch.

    Ring over a random permutation + ``_CHORDS`` random circulant chord
    offsets per graph (out-degree = in-degree = 1 + ``_CHORDS``) + self
    loops — the sparse-overlay family the search explores.
    """
    E = n * (2 + _CHORDS)
    src = np.empty((b, E), dtype=np.int32)
    dst = np.empty((b, E), dtype=np.int32)
    w = np.empty((b, E), dtype=np.float64)
    idx = np.arange(n, dtype=np.int32)
    for k in range(b):
        perm = rng.permutation(n).astype(np.int32)
        cols = [(perm, np.roll(perm, -1))]  # ring
        offsets = rng.choice(np.arange(2, n - 1), size=_CHORDS, replace=False)
        for off in offsets:
            cols.append((idx, (idx + off) % n))
        cols.append((idx, idx))  # self loops
        src[k] = np.concatenate([s for (s, _) in cols])
        dst[k] = np.concatenate([d for (_, d) in cols])
        w[k] = rng.uniform(0.5, 20.0, E)
        w[k, -n:] = rng.uniform(0.0, 5.0, n)  # computation self-delays
    return EdgeBatch(src, dst, w, n)


def _time(fn, repeats: int = 2) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3  # ms


def bench_scoring() -> Dict[str, Dict[str, float]]:
    try:
        import jax

        jit_sparse = jax.jit(batched_cycle_time_sparse_jax, static_argnums=3)
        have_jax = True
    except Exception:
        have_jax = False

    print("# batched cycle-time scoring of sparse (degree<=8) overlays")
    print("sparse_search,score,N,B,E,dense_ms,sp64_ms,sp32_ms,spjax_ms")
    out: Dict[str, Dict[str, float]] = {}
    for n, (b, b_dense) in _SCORING_GRID.items():
        rng = np.random.default_rng(n)
        eb = random_sparse_overlays(rng, n, b)
        W = edge_batch_to_dense(eb).astype(np.float32)

        dense_sub_ms = _time(
            lambda: batched_cycle_time(W[:b_dense], dtype=np.float32),
            repeats=2 if n < 1024 else 1,
        )
        dense_ms = dense_sub_ms * (b / b_dense)
        approx = "~" if b_dense < b else ""

        sp64_ms = _time(lambda: batched_cycle_time_sparse(eb))
        eb32 = EdgeBatch(eb.src, eb.dst, eb.w.astype(np.float32), n)
        sp32_ms = _time(lambda: batched_cycle_time_sparse(eb32))
        if have_jax:
            w32 = eb32.w
            jit_sparse(eb.src, eb.dst, w32, n).block_until_ready()  # compile
            spjax_ms = _time(
                lambda: jit_sparse(eb.src, eb.dst, w32, n).block_until_ready()
            )
            jax_str = f"{spjax_ms:.2f}"
        else:
            spjax_ms, jax_str = float("inf"), "n/a"

        # correctness spot check: sparse f64 == dense f64 on a subsample
        ref = batched_cycle_time(edge_batch_to_dense(eb)[:2])
        got = batched_cycle_time_sparse(
            EdgeBatch(eb.src[:2], eb.dst[:2], eb.w[:2], n)
        )
        np.testing.assert_allclose(got, ref, rtol=1e-9)

        print(
            f"sparse_search,score,{n},{b},{eb.max_edges},{approx}{dense_ms:.2f},"
            f"{sp64_ms:.2f},{sp32_ms:.2f},{jax_str}"
        )
        best_sparse = min(sp64_ms, sp32_ms, spjax_ms)
        out[f"N{n}"] = {
            "batch": b,
            "edges": eb.max_edges,
            "dense_f32_ms": dense_ms,
            "sparse_f64_ms": sp64_ms,
            "sparse_f32_ms": sp32_ms,
            "sparse_jax_ms": spjax_ms if math.isfinite(spjax_ms) else None,
            "speedup_vs_dense": dense_ms / best_sparse,
        }
        if n == 1024:
            print(
                f"# acceptance N=1024: sparse {best_sparse:.1f} ms vs dense "
                f"{dense_ms:.1f} ms ({dense_ms / best_sparse:.1f}x)"
            )
            assert best_sparse < dense_ms, (
                f"sparse path ({best_sparse:.1f} ms) does not beat dense "
                f"({dense_ms:.1f} ms) at N=1024"
            )
    return out


def bench_gaia_search(
    n_restarts: int = 16, n_steps: int = 96
) -> Dict[str, float]:
    M, Tc = C.WORKLOADS["inaturalist"]
    tp = C.TrainingParams(model_size_mbits=M, local_steps=1)
    u = C.make_underlay("gaia")
    gc = u.connectivity_graph(comp_time_ms=Tc)

    # warm up (jit compile + numpy allocator), then time the real run
    search_overlays_jit(gc, tp, n_restarts=n_restarts, n_steps=n_steps, seed=0)
    t0 = time.perf_counter()
    ov = search_overlays_jit(
        gc, tp, n_restarts=n_restarts, n_steps=n_steps, seed=1
    )
    search_s = time.perf_counter() - t0

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    ring256 = search_ring_candidates(gc, tp, 256, rng)
    ring256_s = time.perf_counter() - t0
    # equal wall-clock budget: as many ring candidates as fit in search_s
    n_equal = max(256, int(256 * search_s / max(ring256_s, 1e-9)))
    t0 = time.perf_counter()
    ring_eq = search_ring_candidates(gc, tp, n_equal, np.random.default_rng(0))
    ring_eq_s = time.perf_counter() - t0

    print("# gaia: jitted rewire search vs random-ring search (equal budget)")
    print(f"sparse_search,gaia,rewire_ms,{search_s*1e3:.1f},"
          f"restarts={n_restarts} steps={n_steps}")
    print(f"sparse_search,gaia,rewire_tau_ms,{ov.cycle_time_ms:.2f},")
    print(f"sparse_search,gaia,ring256_tau_ms,{ring256.cycle_time_ms:.2f},"
          f"{ring256_s*1e3:.1f}ms")
    print(f"sparse_search,gaia,ring_equal_tau_ms,{ring_eq.cycle_time_ms:.2f},"
          f"candidates={n_equal} in {ring_eq_s*1e3:.1f}ms")
    assert ov.cycle_time_ms <= ring256.cycle_time_ms + 1e-9, (
        f"rewire search tau {ov.cycle_time_ms:.2f} worse than 256-ring "
        f"search {ring256.cycle_time_ms:.2f}"
    )
    assert ov.cycle_time_ms <= ring_eq.cycle_time_ms + 1e-9, (
        f"rewire search tau {ov.cycle_time_ms:.2f} worse than equal-budget "
        f"ring search {ring_eq.cycle_time_ms:.2f} ({n_equal} candidates)"
    )
    return {
        "network": u.name,
        "num_silos": u.num_silos,
        "rewire_s": search_s,
        "rewire_tau_ms": ov.cycle_time_ms,
        "ring256_s": ring256_s,
        "ring256_tau_ms": ring256.cycle_time_ms,
        "ring_equal_candidates": n_equal,
        "ring_equal_tau_ms": ring_eq.cycle_time_ms,
    }


def run() -> Dict[str, Dict]:
    scoring = bench_scoring()
    print()
    gaia = bench_gaia_search()
    print()
    return {"scoring": scoring, "gaia_search": gaia}


if __name__ == "__main__":
    run()
