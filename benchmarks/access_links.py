"""Fig. 3a/3b: effect of access link capacity on cycle time (Géant).

3a: all access links swept together — for slow links the RING/dMBST
    (degree-bounded) overlays dominate; the paper's closed form says
    RING is up to 2N x faster than STAR.
3b: the STAR center keeps a 10 Gbps link while the rest are swept —
    STAR improves but stays ~2x slower than RING."""

from __future__ import annotations

from .common import cycle_times_for_network


CAPS = (0.1, 0.5, 1.0, 2.0, 6.0, 10.0)


def run() -> None:
    print("# Fig 3a — Géant, all access links at capacity C (ms)")
    print(f"{'C(Gbps)':>8s} {'STAR':>9s} {'MATCHA+':>9s} {'MST':>9s} {'dMBST':>9s} {'RING':>9s} {'star/ring':>10s}")
    for cap in CAPS:
        ct = cycle_times_for_network("geant", access_gbps=cap)
        print(f"{cap:8.1f} {ct['star']:9.0f} {ct['matcha+']:9.0f} {ct['mst']:9.0f} "
              f"{ct['delta_mbst']:9.0f} {ct['ring']:9.0f} {ct['star']/ct['ring']:10.1f}")
    print()
    print("# Fig 3b — Géant, center keeps 10 Gbps, others at C (ms)")
    print(f"{'C(Gbps)':>8s} {'STAR':>9s} {'MST':>9s} {'dMBST':>9s} {'RING':>9s} {'star/ring':>10s}")
    for cap in CAPS:
        ct = cycle_times_for_network("geant", access_gbps=cap,
                                     center_access_gbps=10.0,
                                     overlays=("star", "mst", "delta_mbst", "ring"))
        print(f"{cap:8.1f} {ct['star']:9.0f} {ct['mst']:9.0f} "
              f"{ct['delta_mbst']:9.0f} {ct['ring']:9.0f} {ct['star']/ct['ring']:10.1f}")
    print()


if __name__ == "__main__":
    run()
