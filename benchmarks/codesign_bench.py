"""(τ, ρ) co-design: batched spectral pricing throughput and the
wall-clock-to-ε payoff of objective="time_to_eps".

Two questions gate whether mixing-rate pricing can live inside the
controller's re-design step:

* **spectral throughput** — ρ of a ``[B, N, N]`` consensus stack in one
  batched SVD vs a per-matrix ``numpy.linalg`` loop, N in {16, 64, 256}
  (matrices/sec, plus the batching speedup).  The matrices are realistic:
  random activation masks over a shared arc pool pushed through
  :func:`repro.core.mixing.batched_mixing_matrices`, the exact layout the
  portfolio prices.
* **time-to-target payoff** — across the network zoo, design once under
  ``objective="tau"`` and once under ``objective="time_to_eps"`` (same
  candidate pool, MATCHA budgets included) and compare predicted wall
  clock to a target consensus error ε: ``rounds = log(1/ε)/(−log ρ)``,
  ``time = rounds · τ``.  The full sweep is slow (Monte-Carlo pricing per
  network) and runs only outside --smoke.

CSV: codesign,<metric>,<value>,<derived>; ``run()`` returns the metrics
dict that ``benchmarks.run --json`` serializes (BENCH_codesign.json).
"""

from __future__ import annotations

import math
import time
from typing import Dict

import numpy as np

import repro.core as C
from repro.core.mixing import (
    batched_mixing_matrices,
    batched_rho,
    schedule_rho,
    wall_clock_to_eps,
)
from repro.dynamics import design_best_schedule

GRID_N = (16, 64, 256)
BATCH = 64
SWEEP_NETWORKS = ("gaia", "aws_na", "geant")
TARGET_EPS = 1e-4
MATCHA_BUDGETS = (0.3, 0.5)


def _consensus_stack(n: int, B: int, seed: int = 0) -> np.ndarray:
    """[B, n, n] local-degree matrices of random activations on G(n, p)."""
    rng = np.random.default_rng(seed)
    pairs = [(i, j) for i in range(n) for j in range(i + 1, n)
             if rng.random() < min(1.0, 8.0 / n)]
    arcs = [a for (i, j) in pairs for a in ((i, j), (j, i))]
    src = np.asarray([a for a, _ in arcs], dtype=np.int64)
    dst = np.asarray([b for _, b in arcs], dtype=np.int64)
    on = rng.random((B, len(pairs))) < 0.6
    masks = np.repeat(on, 2, axis=1).astype(np.float64)
    return batched_mixing_matrices(n, src, dst, masks)


def bench_spectral(n: int, B: int = BATCH) -> Dict[str, float]:
    W = _consensus_stack(n, B)
    deflate = W - 1.0 / n
    # warmup both paths (LAPACK workspace, allocator)
    batched_rho(W[:2])
    np.linalg.svd(deflate[0], compute_uv=False)
    t0 = time.perf_counter()
    rho_b = batched_rho(W)
    batched_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    rho_l = np.asarray(
        [np.linalg.svd(deflate[k], compute_uv=False)[0] for k in range(B)]
    )
    loop_s = time.perf_counter() - t0
    assert np.array_equal(rho_b, rho_l)  # same LAPACK driver per slice
    return {
        "n": n,
        "batch": B,
        "batched_s": batched_s,
        "loop_s": loop_s,
        "matrices_per_sec": B / batched_s,
        "speedup": loop_s / batched_s,
    }


def bench_time_to_target(network: str) -> Dict[str, float]:
    """Predicted wall clock to ε under each objective's winning design."""
    M, Tc = C.WORKLOADS["inaturalist"]
    tp = C.TrainingParams(model_size_mbits=M, local_steps=1)
    u = C.make_underlay(network)
    gc = u.connectivity_graph(comp_time_ms=Tc)
    kw = dict(
        n_candidates=64,
        rewire_restarts=0,
        matcha_budgets=MATCHA_BUDGETS,
        matcha_rounds=100,
        matcha_seeds=(0, 1),
    )
    out: Dict[str, float] = {"network": network, "num_silos": u.num_silos}
    horizon = math.log(1.0 / TARGET_EPS)
    for objective in ("tau", "time_to_eps"):
        sched, _ = design_best_schedule(gc, tp, objective=objective, **kw)
        est = sched.price(gc, tp, rounds=100, seeds=(0, 1))
        rho = schedule_rho(sched, gc, rounds=128)
        out[f"{objective}_pick"] = sched.name
        out[f"{objective}_tau_ms"] = est.tau_ms
        out[f"{objective}_rho"] = rho
        out[f"{objective}_time_to_eps_ms"] = horizon * wall_clock_to_eps(
            est.tau_ms, rho
        )
    t_tau = out["tau_time_to_eps_ms"]
    t_eps = out["time_to_eps_time_to_eps_ms"]
    # The co-designed pick can never predict worse on its own objective.
    assert t_eps <= t_tau * (1.0 + 1e-9), (network, t_tau, t_eps)
    out["speedup_vs_tau_design"] = t_tau / t_eps
    return out


def run(smoke: bool = False) -> Dict[str, Dict[str, float]]:
    print("# codesign: batched rho pricing + time-to-target payoff")
    metrics: Dict[str, Dict[str, float]] = {}
    grid = (16,) if smoke else GRID_N
    batch = 8 if smoke else BATCH
    for n in grid:
        sp = bench_spectral(n, batch)
        metrics[f"spectral_n{n}"] = sp
        print(f"codesign,rho_batched_ms_n{n},{sp['batched_s']*1e3:.2f},"
              f"B={sp['batch']} speedup={sp['speedup']:.1f}x")
        print(f"codesign,rho_matrices_per_sec_n{n},"
              f"{sp['matrices_per_sec']:.0f},")
    if smoke:
        # one cheap end-to-end arbitration so the objective plumbing runs
        # in CI without the Monte-Carlo zoo sweep
        tt = bench_time_to_target("gaia")
        metrics["time_to_target_gaia"] = tt
        print(f"codesign,gaia_speedup_vs_tau_design,"
              f"{tt['speedup_vs_tau_design']:.2f},"
              f"{tt['tau_pick']} -> {tt['time_to_eps_pick']}")
        return metrics
    for network in SWEEP_NETWORKS:
        tt = bench_time_to_target(network)
        metrics[f"time_to_target_{network}"] = tt
        print(f"codesign,{network}_speedup_vs_tau_design,"
              f"{tt['speedup_vs_tau_design']:.2f},"
              f"{tt['tau_pick']} -> {tt['time_to_eps_pick']} "
              f"N={tt['num_silos']}")
    return metrics


if __name__ == "__main__":
    run()
