# One function per paper table/figure. Prints aligned tables plus
# ``name,us_per_call,derived`` CSV lines for the scalar benches; benches
# that return a metrics dict feed the machine-readable --json report.
#
# ``--smoke`` is the CI gate (scripts/ci.sh): benches whose ``run()``
# accepts a ``smoke`` kwarg execute a seconds-scale configuration (tiny
# grids, perf asserts off — correctness asserts stay on); benches without
# one are skipped with a note. This keeps bench code imported and
# executed on every CI run so it cannot silently rot.
import argparse
import inspect
import json
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="write collected bench metrics to this JSON file")
    ap.add_argument("--only", default="",
                    help="run only benches whose module name contains this")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI mode: run smoke-capable benches on tiny "
                         "configs, skip the rest")
    args = ap.parse_args()

    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    t0 = time.time()
    from . import table3, local_steps, access_links, speedup_vs_s
    from . import analytic, matcha_budget, table9, kernel_bench, gossip_bench
    from . import maxplus_bench, dynamics_bench, sparse_search_bench
    from . import codesign_bench

    metrics = {}
    for mod in (table3, local_steps, access_links, speedup_vs_s, analytic,
                matcha_budget, table9, gossip_bench, kernel_bench,
                maxplus_bench, dynamics_bench, sparse_search_bench,
                codesign_bench):
        name = mod.__name__.split(".")[-1]
        if args.only and args.only not in name:
            continue
        smoke_capable = "smoke" in inspect.signature(mod.run).parameters
        if args.smoke and not smoke_capable:
            print(f"==== {name} — skipped (no smoke mode)")
            continue
        print(f"==== {name} " + "=" * (60 - len(name)))
        t = time.time()
        out = mod.run(smoke=True) if args.smoke and smoke_capable else mod.run()
        if isinstance(out, dict):
            metrics[name] = out
        print(f"[{name} done in {time.time()-t:.1f}s]\n")
    if args.json:
        # Provenance stamp: BENCH numbers are only comparable across runs
        # of the same rev / jax / device, so say which this was.
        from repro.obs.events import run_metadata

        metrics["_meta"] = run_metadata({"smoke": bool(args.smoke)})
        with open(args.json, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"metrics -> {args.json}")
    print(f"ALL BENCHMARKS DONE in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
