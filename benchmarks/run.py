# One function per paper table/figure. Prints aligned tables plus
# ``name,us_per_call,derived`` CSV lines for the scalar benches; benches
# that return a metrics dict feed the machine-readable --json report.
import argparse
import json
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="write collected bench metrics to this JSON file")
    ap.add_argument("--only", default="",
                    help="run only benches whose module name contains this")
    args = ap.parse_args()

    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    t0 = time.time()
    from . import table3, local_steps, access_links, speedup_vs_s
    from . import analytic, matcha_budget, table9, kernel_bench, gossip_bench
    from . import maxplus_bench, dynamics_bench, sparse_search_bench

    metrics = {}
    for mod in (table3, local_steps, access_links, speedup_vs_s, analytic,
                matcha_budget, table9, gossip_bench, kernel_bench,
                maxplus_bench, dynamics_bench, sparse_search_bench):
        name = mod.__name__.split(".")[-1]
        if args.only and args.only not in name:
            continue
        print(f"==== {name} " + "=" * (60 - len(name)))
        t = time.time()
        out = mod.run()
        if isinstance(out, dict):
            metrics[name] = out
        print(f"[{name} done in {time.time()-t:.1f}s]\n")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"metrics -> {args.json}")
    print(f"ALL BENCHMARKS DONE in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
