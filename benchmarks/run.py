# One function per paper table/figure. Prints aligned tables plus
# ``name,us_per_call,derived`` CSV lines for the scalar benches.
import os
import sys
import time


def main() -> None:
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    t0 = time.time()
    from . import table3, local_steps, access_links, speedup_vs_s
    from . import analytic, matcha_budget, table9, kernel_bench, gossip_bench
    from . import maxplus_bench

    for mod in (table3, local_steps, access_links, speedup_vs_s, analytic,
                matcha_budget, table9, gossip_bench, kernel_bench,
                maxplus_bench):
        name = mod.__name__.split(".")[-1]
        print(f"==== {name} " + "=" * (60 - len(name)))
        t = time.time()
        mod.run()
        print(f"[{name} done in {time.time()-t:.1f}s]\n")
    print(f"ALL BENCHMARKS DONE in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
