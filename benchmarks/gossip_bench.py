"""Gossip collective-schedule benchmark: the paper's thesis on TPU.

For each topology, compile the DPASGD gossip over an 8-silo host mesh
and measure (a) the collective bytes in the lowered HLO and (b) wall
time.  The Birkhoff/ppermute schedule's traffic must scale with overlay
degree (ring: 1 transfer) while the naive einsum mix all-gathers —
exactly the STAR-vs-RING gap predicted by the max-plus model.
CSV: name,us_per_call,collective_bytes."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.fed.gossip import GossipPlan, gossip_einsum, gossip_shard_map
from repro.fed.topology_runtime import plan_for_n_silos
from repro.launch.hlo_analysis import collective_bytes


def run() -> None:
    n_dev = len(jax.devices())
    n = min(8, n_dev)
    if n < 2:
        print("gossip_bench,skipped,single-device-host")
        return
    mesh = jax.make_mesh((n,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    D = 1 << 18
    params = {"w": jnp.arange(n * D, dtype=jnp.float32).reshape(n, D) / (n * D)}
    sh = NamedSharding(mesh, P("data", None))
    params = jax.tree_util.tree_map(lambda x: jax.device_put(x, sh), params)

    results = {}
    for kind in ("ring", "chain", "star"):
        plan = plan_for_n_silos(kind, n)

        def mix(p, plan=plan):
            return gossip_shard_map(p, plan, mesh, "data")

        with jax.set_mesh(mesh):
            jitted = jax.jit(mix)
            lowered = jitted.lower(params)
            compiled = lowered.compile()
            cb = collective_bytes(compiled.as_text())
            total = sum(v for k, v in cb.items() if k != "collective-count")
            out = jitted(params)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(5):
                jax.block_until_ready(jitted(params))
            us = (time.perf_counter() - t0) / 5 * 1e6
        results[kind] = (us, total, plan.num_transfers)
        print(f"gossip_{kind},{us:.1f},coll_bytes={total} transfers={plan.num_transfers}")

    # naive einsum reference (dense mixing -> all-gather style traffic)
    A = jnp.asarray(plan_for_n_silos("ring", n).matrix)

    def mix_dense(p):
        return gossip_einsum(p, A)

    with jax.set_mesh(mesh):
        jitted = jax.jit(mix_dense)
        compiled = jitted.lower(params).compile()
        cb = collective_bytes(compiled.as_text())
        total = sum(v for k, v in cb.items() if k != "collective-count")
        jax.block_until_ready(jitted(params))
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(jitted(params))
        us = (time.perf_counter() - t0) / 5 * 1e6
    print(f"gossip_einsum_ring,{us:.1f},coll_bytes={total}")
    ring_bytes = results["ring"][1]
    star_bytes = results["star"][1]
    print(f"# ring vs star collective bytes: {ring_bytes} vs {star_bytes} "
          f"(ratio {star_bytes / max(ring_bytes,1):.1f}x — the paper's degree argument)")
    print()


if __name__ == "__main__":
    run()
