"""Shared helpers for the paper-table benchmarks."""

from __future__ import annotations

import time
from typing import Dict, Optional

import repro.core as C

# Table 3 reference values (ms): STAR, MATCHA+, MST, dMBST, RING
PAPER_TABLE3 = {
    "gaia": (391, 228, 138, 138, 118),
    "aws_na": (288, 124, 90, 90, 81),
    "geant": (634, 106, 101, 101, 109),
    "exodus": (912, 142, 145, 145, 103),
    "ebone": (902, 123, 122, 122, 95),
}


def cycle_times_for_network(
    name: str,
    workload: str = "inaturalist",
    *,
    core_gbps: float = 1.0,
    access_gbps: float = 10.0,
    local_steps: int = 1,
    center_access_gbps: Optional[float] = None,
    matcha_budget: float = 0.5,
    matcha_rounds: int = 150,
    overlays=("star", "matcha+", "mst", "delta_mbst", "ring"),
) -> Dict[str, float]:
    M, Tc = C.WORKLOADS[workload]
    tp = C.TrainingParams(model_size_mbits=M, local_steps=local_steps)
    u = C.make_underlay(name, core_capacity_gbps=core_gbps,
                        access_capacity_gbps=access_gbps)
    per_silo_access = None
    center = u.load_centrality_center()
    if center_access_gbps is not None:
        per_silo_access = {center: center_access_gbps}
    gc = u.connectivity_graph(comp_time_ms=Tc,
                              per_silo_access_gbps=per_silo_access)
    out: Dict[str, float] = {}
    for kind in overlays:
        # MATCHA rows price through the batched schedule path — identical
        # numbers to the legacy scalar loop at seed 0 (tested seeded
        # equivalence), at a fraction of the wall time.
        if kind == "matcha+":
            s = C.matcha_schedule_from_underlay(u, matcha_budget)
            out[kind] = s.price(gc, tp, rounds=matcha_rounds).tau_ms
        elif kind == "matcha":
            s = C.matcha_schedule_from_connectivity(gc, matcha_budget)
            out[kind] = s.price(gc, tp, rounds=matcha_rounds).tau_ms
        elif kind == "star":
            out[kind] = C.star_overlay(gc, tp, center=center).cycle_time_ms
        else:
            out[kind] = C.design_overlay(kind, gc, tp).cycle_time_ms
    return out


def emit(name: str, value_ms: float, derived: str = "") -> None:
    print(f"{name},{value_ms * 1000:.1f},{derived}")
