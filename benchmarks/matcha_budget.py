"""Table 10 + the randomized-schedule engine bench.

Part 1 reproduces Table 10 (RING speedup vs MATCHA+ across communication
budgets C_b on AWS North America; 10 Gbps and 100 Mbps access links),
now priced through the batched schedule path — one
``average_cycle_times_batched`` sweep per row instead of a scalar
``random.Random`` dict loop per cell.  The numbers are *identical* to
the legacy loop (seeded equivalence, see ``tests/test_schedule.py``);
only the wall clock changes.

Part 2 is the engine benchmark behind ``BENCH_matcha.json``: legacy
scalar :meth:`Matcha.average_cycle_time` vs the batched budgets × seeds
Monte-Carlo sweep on a synthetic N=64 random-geometric network
(degree-8 base graph), R=300 rounds, 8 budgets × 8 seeds.  Both paths
consume the same seeded activation streams, so the τ̄ grids must agree
exactly — the speedup is pure engine (vectorized Eq. 3 pricing via
``batched_overlay_delay_edges``'s degree table + the unique-rounds
edge-list recursion).  The legacy loop scales linearly in seeds while
the batched path amortizes (activation-subset dedup, shared pricing),
so more Monte-Carlo chains — the whole point of the batched sweep —
widen the gap.

CSV: ``matcha,N,R,budgets,seeds,legacy_s,batched_s,speedup,max_rel_diff``
Acceptance target: >= 20x at N=64, R=300, 8 budgets (asserted outside
``--smoke``; the checked-in BENCH_matcha.json records a passing run).
"""

from __future__ import annotations

import time
from typing import Dict, Tuple

import numpy as np

import repro.core as C
from repro.core.delays import ConnectivityGraph, SiloParams, TrainingParams
from repro.core.matcha import Matcha, greedy_edge_coloring

ENGINE_BUDGETS = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.8, 1.0)
ENGINE_SEEDS = tuple(range(8))


def synthetic_geometric_gc(
    n: int, degree: int, seed: int = 0
) -> Tuple[ConnectivityGraph, list]:
    """Random-geometric N-silo connectivity graph + a degree-bounded
    random base-pair set (the MATCHA base graph)."""
    rng = np.random.default_rng(seed)
    xy = rng.uniform(0.0, 1.0, (n, 2))
    lat = {}
    bw = {}
    for i in range(n):
        for j in range(n):
            if i != j:
                d = float(np.hypot(*(xy[i] - xy[j])))
                lat[(i, j)] = 10.0 + 100.0 * d
                bw[(i, j)] = 1.0
    params = {
        v: SiloParams(
            comp_time_ms=float(rng.uniform(2.0, 6.0)),
            uplink_gbps=10.0,
            downlink_gbps=10.0,
        )
        for v in range(n)
    }
    gc = ConnectivityGraph(
        silos=tuple(range(n)),
        latency_ms=lat,
        available_bw_gbps=bw,
        silo_params=params,
    )
    pairs = sorted(
        {
            (i, int(j))
            for i in range(n)
            for j in rng.choice(n, degree, replace=False)
            if i < j
        }
    )
    return gc, pairs


def _table10(smoke: bool) -> None:
    budgets = (1.0, 0.8, 0.6, 0.5, 0.4, 0.2, 0.1)
    rounds = 30 if smoke else 120
    M, Tc = C.WORKLOADS["inaturalist"]
    tp = TrainingParams(model_size_mbits=M, local_steps=1)
    print("# Table 10 — ring speedup vs MATCHA+ for various C_b (AWS NA)")
    print(f"{'access':>8s} " + " ".join(f"Cb={cb:<4}" for cb in budgets))
    accesses = (10.0,) if smoke else (10.0, 0.1)
    for access in accesses:
        u = C.make_underlay("aws_na", access_capacity_gbps=access)
        gc = u.connectivity_graph(comp_time_ms=Tc)
        ring = C.ring_overlay(gc, tp).cycle_time_ms
        scheds = [
            C.matcha_schedule_from_underlay(u, cb) for cb in budgets
        ]
        taus = C.average_cycle_times_batched(
            scheds, gc, tp, rounds=rounds, seeds=(0,)
        )[:, 0]
        label = f"{access:5.1f}G" if access >= 1 else f"{access*1000:4.0f}M"
        print(f"{label:>8s} " + " ".join(f"{t / ring:7.2f}" for t in taus))
    print()


def run(smoke: bool = False, assert_speedup: bool = True) -> Dict[str, float]:
    _table10(smoke)

    n, degree = (16, 4) if smoke else (64, 8)
    rounds = 60 if smoke else 300
    budgets = ENGINE_BUDGETS[:3] if smoke else ENGINE_BUDGETS
    seeds = ENGINE_SEEDS[:1] if smoke else ENGINE_SEEDS
    M, Tc = C.WORKLOADS["inaturalist"]
    tp = TrainingParams(model_size_mbits=M, local_steps=1)
    gc, pairs = synthetic_geometric_gc(n, degree)
    matchings = tuple(tuple(m) for m in greedy_edge_coloring(pairs))

    # Symmetric methodology: both sides timed as min-of-2 full runs (the
    # container's wall clock swings 2x+ with load; min-of-k estimates the
    # quiet-box cost for legacy and batched alike).
    def _legacy():
        return np.array(
            [
                [
                    Matcha(matchings=[list(m) for m in matchings], budget=b)
                    .average_cycle_time(gc, tp, rounds=rounds, seed=s)
                    for s in seeds
                ]
                for b in budgets
            ]
        )

    scheds = [
        C.MatchaSchedule(matchings=matchings, budget=b) for b in budgets
    ]

    def _batched():
        return C.average_cycle_times_batched(
            scheds, gc, tp, rounds=rounds, seeds=seeds
        )

    reps = 1 if smoke else 2
    legacy_s, batched_s = float("inf"), float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        legacy = _legacy()
        legacy_s = min(legacy_s, time.perf_counter() - t0)
        t0 = time.perf_counter()
        taus = _batched()
        batched_s = min(batched_s, time.perf_counter() - t0)

    max_rel = float(np.max(np.abs(taus - legacy) / legacy))
    speedup = legacy_s / batched_s
    print(
        "# randomized-schedule pricing: legacy scalar loop vs batched "
        "budgets x seeds sweep"
    )
    print("matcha,N,R,budgets,seeds,legacy_s,batched_s,speedup,max_rel_diff")
    print(
        f"matcha,{n},{rounds},{len(budgets)},{len(seeds)},{legacy_s:.3f},"
        f"{batched_s:.4f},{speedup:.1f},{max_rel:.1e}"
    )
    assert max_rel < 1e-6, (
        f"batched tau-bar diverged from the legacy oracle by {max_rel:.2e}"
    )
    if not smoke:
        print(
            f"# acceptance N={n} R={rounds} {len(budgets)} budgets: "
            f"{speedup:.1f}x (target >= 20x; BENCH_matcha.json records a "
            f"passing run)"
        )
        if assert_speedup:
            # Loose complexity-class guard per docs/benchmarks.md: the
            # legacy side's wall clock swings 2x+ with container load, so
            # the hard assert sits well under the 20x acceptance target.
            assert speedup >= 8.0, (
                f"batched matcha pricing only {speedup:.1f}x over the "
                f"legacy loop at N={n}, R={rounds}"
            )
    print()
    return {
        "n_silos": n,
        "rounds": rounds,
        "n_budgets": len(budgets),
        "n_seeds": len(seeds),
        "legacy_s": round(legacy_s, 3),
        "batched_s": round(batched_s, 4),
        "speedup": round(speedup, 1),
        "max_rel_diff": max_rel,
    }


if __name__ == "__main__":
    run()
