"""Table 10: RING speedup vs MATCHA+ across communication budgets C_b
(AWS North America; 10 Gbps and 100 Mbps access links)."""

from __future__ import annotations

import repro.core as C
from repro.core.delays import TrainingParams


def run() -> None:
    M, Tc = C.WORKLOADS["inaturalist"]
    tp = TrainingParams(model_size_mbits=M, local_steps=1)
    print("# Table 10 — ring speedup vs MATCHA+ for various C_b (AWS NA)")
    print(f"{'access':>8s} " + " ".join(f"Cb={cb:<4}" for cb in (1.0, 0.8, 0.6, 0.5, 0.4, 0.2, 0.1)))
    for access in (10.0, 0.1):
        u = C.make_underlay("aws_na", access_capacity_gbps=access)
        gc = u.connectivity_graph(comp_time_ms=Tc)
        ring = C.ring_overlay(gc, tp).cycle_time_ms
        row = []
        for cb in (1.0, 0.8, 0.6, 0.5, 0.4, 0.2, 0.1):
            m = C.matcha_plus_from_underlay(u, cb)
            ct = m.average_cycle_time(gc, tp, rounds=120)
            row.append(f"{ct / ring:7.2f}")
        label = f"{access:5.1f}G" if access >= 1 else f"{access*1000:4.0f}M"
        print(f"{label:>8s} " + " ".join(row))
    print()


if __name__ == "__main__":
    run()
