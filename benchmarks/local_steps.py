"""Tables 6-7: effect of the number of local steps s (5 and 10).

As s grows the computation term s*T_c dominates Eq. 3 and overlay
throughputs converge (Sect. 4 / Fig. 4 discussion)."""

from __future__ import annotations

from .common import cycle_times_for_network
import repro.core as C

PAPER = {  # (STAR, MST, RING) for s=5 and s=10 (Tables 6, 7)
    5: {"gaia": (492.4, 239.7, 219.7), "aws_na": (389.8, 191.3, 182.9),
        "geant": (736.0, 202.6, 210.6), "exodus": (1013.4, 246.9, 205.5),
        "ebone": (1003.2, 223.2, 196.9)},
    10: {"gaia": (619.4, 366.7, 346.7), "aws_na": (516.8, 318.3, 309.9),
         "geant": (609.0, 329.6, 337.6), "exodus": (1140.4, 373.9, 332.5),
         "ebone": (1130.2, 350.4, 323.9)},
}


def run() -> None:
    for s in (5, 10):
        print(f"# Table {'6' if s == 5 else '7'} — cycle time (ms), s={s}")
        print(f"{'network':8s} {'STAR':>16s} {'MST':>16s} {'RING':>16s} {'ring/star':>10s}")
        for name in C.NETWORK_NAMES:
            ct = cycle_times_for_network(name, local_steps=s,
                                         overlays=("star", "mst", "ring"))
            p = PAPER[s][name]
            print(f"{name:8s} {ct['star']:7.0f} [{p[0]:6.1f}] "
                  f"{ct['mst']:7.0f} [{p[1]:6.1f}] {ct['ring']:7.0f} [{p[2]:6.1f}]"
                  f" {ct['star']/ct['ring']:10.2f}")
        print()


if __name__ == "__main__":
    run()
