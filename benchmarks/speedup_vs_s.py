"""Fig. 4: throughput speedup vs STAR as the number of local steps s
grows (Exodus, all links 1 Gbps).  With more local computation the
communication term loses weight and all overlays converge to 1x."""

from __future__ import annotations

from .common import cycle_times_for_network


def run() -> None:
    print("# Fig 4 — Exodus, all links 1 Gbps: throughput speedup vs STAR")
    print(f"{'s':>4s} {'MATCHA+':>9s} {'MST':>9s} {'dMBST':>9s} {'RING':>9s}")
    for s in (1, 2, 4, 8, 16, 32, 64):
        ct = cycle_times_for_network("exodus", access_gbps=1.0, local_steps=s)
        star = ct["star"]
        print(f"{s:4d} {star/ct['matcha+']:9.2f} {star/ct['mst']:9.2f} "
              f"{star/ct['delta_mbst']:9.2f} {star/ct['ring']:9.2f}")
    print()


if __name__ == "__main__":
    run()
