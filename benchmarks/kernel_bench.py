"""Pallas kernel microbenchmarks (interpret mode on CPU — numbers are
for regression tracking, not TPU performance).  CSV: name,us_per_call,derived."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.maxplus_vec import batched_cycle_time, batched_cycle_time_jax
from repro.kernels import ops, ref


def _bench(fn, iters: int = 3) -> float:
    jax.block_until_ready(fn())  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> None:
    key = jax.random.PRNGKey(0)
    B, S, K, G, hd = 1, 512, 2, 2, 64
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, K, G, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)

    us = _bench(lambda: ops.flash_attention(q, k, v, block_q=128, block_kv=128))
    us_ref = _bench(lambda: ref.attention_ref(q, k, v))
    print(f"flash_attention_512,{us:.1f},ref_us={us_ref:.1f}")

    nb = jax.random.normal(ks[3], (4, 1 << 20))
    w = jnp.array([0.4, 0.3, 0.2, 0.1])
    us = _bench(lambda: ops.gossip_mix(nb, w))
    us_ref = _bench(lambda: ref.gossip_mix_ref(nb, w))
    print(f"gossip_mix_4x1M,{us:.1f},ref_us={us_ref:.1f}")

    B2, S2, H2, hd2 = 1, 512, 2, 64
    q2 = jax.random.normal(ks[0], (B2, S2, H2, hd2)) * 0.5
    k2 = jax.random.normal(ks[1], (B2, S2, H2, hd2)) * 0.5
    v2 = jax.random.normal(ks[2], (B2, S2, H2, hd2)) * 0.5
    li = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B2, S2, H2)))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B2, S2, H2)) + 2)
    us = _bench(lambda: ops.mlstm_scan(q2, k2, v2, li, lf, chunk=128))
    us_ref = _bench(lambda: ref.mlstm_scan_ref(q2, k2, v2, li, lf))
    print(f"mlstm_scan_512,{us:.1f},ref_us={us_ref:.1f}")

    # Batched max-plus cycle-time engine: XLA scan vs the numpy sweep.
    rng = np.random.default_rng(0)
    Bc, Nc = 256, 32
    Wc = np.where(
        rng.random((Bc, Nc, Nc)) < 0.2,
        rng.uniform(0.5, 20.0, (Bc, Nc, Nc)),
        -np.inf,
    ).astype(np.float32)
    idx = np.arange(Nc)
    Wc[:, idx, (idx + 1) % Nc] = 1.0
    cyc = jax.jit(batched_cycle_time_jax)
    us = _bench(lambda: cyc(Wc))
    us_ref = _bench(lambda: batched_cycle_time(Wc, dtype=np.float32))
    print(f"batched_cycle_time_256x32,{us:.1f},numpy_us={us_ref:.1f}")
    print()


if __name__ == "__main__":
    run()
